//! Metrics export: Prometheus-text and JSON renderers over the engine's
//! `Metrics` / `ServingMetrics`, plus the live [`CodeOccupancy`] probes.
//!
//! The Prometheus renderer emits the standard text exposition format:
//! counters as `nxfp_*_total`, gauges bare, histograms as cumulative
//! `_bucket{le="..."}` series over the log-spaced bucket geometry
//! `Histogram` already uses (bound of bucket *i* is `lo·growth^(i+1)`),
//! with zero-count buckets elided — cumulative sums stay valid — and the
//! mandatory `le="+Inf"` / `_sum` / `_count` terminators. The JSON
//! renderer carries the same counters plus per-histogram summaries
//! (count/sum/mean/p50/p95/min/max); both are hand-rolled like the rest
//! of the repo's JSON (no serde).
//!
//! [`write_metrics`] picks the renderer from the file extension
//! (`.json` → JSON, anything else → Prometheus text), so
//! `--metrics-out metrics.prom` and `--metrics-out metrics.json` both
//! do the obvious thing.

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

use crate::coordinator::metrics::{Histogram, ServingMetrics};
use crate::coordinator::Metrics;
use crate::obs::occupancy::CodeOccupancy;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn prom_counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn prom_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn prom_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    // zero-count buckets add nothing to the cumulative sum, so eliding
    // them keeps the series exact while keeping 100+-bucket histograms
    // readable
    let mut cum = 0u64;
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let _ = writeln!(out, "{name}_bucket{{le=\"{:.6e}\"}} {cum}", h.bucket_bound(i));
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

fn serving_counters(s: &ServingMetrics) -> [(&'static str, &'static str, u64); 20] {
    [
        ("admitted", "requests admitted into the batch", s.admitted),
        ("promoted", "admissions via the anti-starvation rule", s.promoted),
        ("rejected", "requests rejected by validation", s.rejected),
        ("prefix_hits", "admissions that adopted cached prefix rows", s.prefix_hits),
        ("prefix_misses", "admissions with no usable cached prefix", s.prefix_misses),
        ("step_faults", "transient faults during batched decode steps", s.step_faults),
        ("chunk_faults", "transient faults during prefill chunks", s.chunk_faults),
        ("nan_faults", "steps rejected by NaN containment", s.nan_faults),
        ("retries", "in-place retries of faulted backend calls", s.retries),
        ("requeued", "slots retired to the queue front after faults", s.requeued),
        ("backend_failed", "requests failed after exhausting retries", s.backend_failed),
        ("shed", "requests dropped by overload policy", s.shed),
        ("deadline_expired", "requests dropped by deadline enforcement", s.deadline_expired),
        ("spec_accepted", "draft tokens accepted by the speculative verifier", s.spec_accepted),
        ("spec_rejected", "speculative verify rounds that rejected a draft", s.spec_rejected),
        ("spec_forced", "verifier bonus tokens from all-accepted rounds", s.spec_forced),
        ("spec_rollback_rows", "draft KV rows rolled back by rejections", s.spec_rollback_rows),
        ("spec_rounds", "speculative verify rounds run", s.spec_rounds),
        ("affinity_overrides", "dispatches steered here by prefix affinity", s.affinity_overrides),
        ("affinity_spills", "affine dispatches that fell through to least-loaded", s.affinity_spills),
    ]
}

fn serving_histograms(s: &ServingMetrics) -> [(&'static str, &'static str, &Histogram); 11] {
    [
        ("latency_seconds", "end-to-end request latency", &s.latency),
        ("ttft_seconds", "time to first generated token", &s.ttft),
        ("wait_steps", "scheduler steps spent queued before admission", &s.wait_steps),
        ("queue_depth", "admission queue depth sampled per step", &s.queue_depth),
        ("prefill_chunk_tokens", "prompt tokens fed per prefill chunk", &s.prefill_chunk),
        ("step_prefill_tokens", "prompt tokens fed per engine step", &s.step_prefill_tokens),
        ("step_decode_tokens", "tokens decoded per engine step", &s.step_decode_tokens),
        ("prefix_rows_adopted", "cached prefix rows adopted per hit", &s.prefix_rows),
        ("shared_pages", "KV pages shared via prefix COW, per step", &s.shared_pages),
        ("retry_backoff_seconds", "backoff slept before each retry", &s.retry_backoff),
        ("spec_accept", "per-round speculative acceptance rate", &s.spec_accept),
    ]
}

/// Derived serving gauges (currently just the speculative acceptance
/// rate — the live draft-vs-verifier fidelity probe). A helper so the
/// single-engine and fleet renderers emit the identical family.
fn serving_gauges(s: &ServingMetrics) -> [(&'static str, &'static str, f64); 1] {
    [(
        "nxfp_spec_accept_rate",
        "accepted draft tokens over all draft tokens judged",
        s.spec_accept_rate(),
    )]
}

/// Render the Prometheus text exposition for one engine's metrics.
pub fn render_prometheus(m: &Metrics, s: &ServingMetrics, occ: &[CodeOccupancy]) -> String {
    let mut out = String::new();
    prom_counter(&mut out, "nxfp_requests_total", "requests completed", m.requests);
    prom_counter(&mut out, "nxfp_tokens_generated_total", "tokens generated", m.tokens_generated);
    prom_counter(&mut out, "nxfp_decode_steps_total", "batched decode steps", m.decode_steps);
    prom_gauge(&mut out, "nxfp_wall_seconds", "wall time spent stepping", m.wall.as_secs_f64());
    prom_gauge(&mut out, "nxfp_tokens_per_sec", "decode throughput", m.tokens_per_sec());
    prom_gauge(&mut out, "nxfp_kv_bits_packed", "packed KV footprint", m.kv_bits_packed as f64);
    prom_gauge(
        &mut out,
        "nxfp_kv_bits_fp16",
        "fp16-equivalent KV footprint",
        m.kv_bits_fp16 as f64,
    );
    prom_gauge(&mut out, "nxfp_kv_savings", "fp16 bits per packed bit", m.kv_savings());
    for (name, help, v) in serving_counters(s) {
        prom_counter(&mut out, &format!("nxfp_{name}_total"), help, v);
    }
    for (name, help, v) in serving_gauges(s) {
        prom_gauge(&mut out, name, help, v);
    }
    for (name, help, h) in serving_histograms(s) {
        prom_histogram(&mut out, &format!("nxfp_{name}"), help, h);
    }
    for o in occ {
        let label = format!("{{config=\"{}\"}}", esc(&o.config));
        let _ = writeln!(out, "# TYPE nxfp_occupancy_elements_total counter");
        let _ = writeln!(out, "nxfp_occupancy_elements_total{label} {}", o.total);
        let _ = writeln!(out, "# TYPE nxfp_occupancy_clipped_total counter");
        let _ = writeln!(out, "nxfp_occupancy_clipped_total{label} {}", o.clipped);
        let _ = writeln!(out, "# TYPE nxfp_occupancy_clip_rate gauge");
        let _ = writeln!(out, "nxfp_occupancy_clip_rate{label} {}", o.clip_rate());
        let _ = writeln!(out, "# TYPE nxfp_occupancy_vacant_fraction gauge");
        let _ = writeln!(out, "nxfp_occupancy_vacant_fraction{label} {}", o.vacant_fraction());
        let _ = writeln!(out, "# TYPE nxfp_occupancy_recycle_rate gauge");
        let _ = writeln!(out, "nxfp_occupancy_recycle_rate{label} {}", o.recycle_rate());
    }
    out
}

fn json_hist(out: &mut String, name: &str, h: &Histogram) {
    let _ = write!(
        out,
        "\"{name}\":{{\"count\":{},\"sum\":{},\"mean\":{},\"p50\":{},\"p95\":{},\
         \"min\":{},\"max\":{}}}",
        h.count(),
        h.sum(),
        h.mean(),
        h.p50(),
        h.p95(),
        h.min(),
        h.max()
    );
}

/// Render the same metrics as one JSON object.
pub fn render_metrics_json(m: &Metrics, s: &ServingMetrics, occ: &[CodeOccupancy]) -> String {
    let mut out = String::from("{");
    let _ = write!(
        out,
        "\"requests\":{},\"tokens_generated\":{},\"decode_steps\":{},\"wall_seconds\":{},\
         \"tokens_per_sec\":{},\"kv_bits_packed\":{},\"kv_bits_fp16\":{},\"kv_savings\":{}",
        m.requests,
        m.tokens_generated,
        m.decode_steps,
        m.wall.as_secs_f64(),
        m.tokens_per_sec(),
        m.kv_bits_packed,
        m.kv_bits_fp16,
        m.kv_savings()
    );
    out.push_str(",\"serving\":{");
    let mut first = true;
    for (name, _, v) in serving_counters(s) {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "\"{name}\":{v}");
    }
    let _ = write!(out, ",\"spec_accept_rate\":{}", s.spec_accept_rate());
    for (name, _, h) in serving_histograms(s) {
        out.push(',');
        json_hist(&mut out, name, h);
    }
    out.push_str("},\"occupancy\":[");
    for (i, o) in occ.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"config\":\"{}\",\"bits\":{},\"total\":{},\"clipped\":{},\"clip_rate\":{},\
             \"vacant_fraction\":{},\"recycle_rate\":{}}}",
            esc(&o.config),
            o.bits,
            o.total,
            o.clipped,
            o.clip_rate(),
            o.vacant_fraction(),
            o.recycle_rate()
        );
    }
    out.push_str("]}\n");
    out
}

/// Emit one histogram's series, optionally labeled `replica="i"`. No
/// HELP/TYPE header — the caller emits that once per metric name, so a
/// rollup series and its per-replica series can share one family.
fn prom_hist_series(out: &mut String, name: &str, h: &Histogram, replica: Option<usize>) {
    let (pre, plain) = match replica {
        Some(i) => (format!("replica=\"{i}\","), format!("{{replica=\"{i}\"}}")),
        None => (String::new(), String::new()),
    };
    let mut cum = 0u64;
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let _ = writeln!(out, "{name}_bucket{{{pre}le=\"{:.6e}\"}} {cum}", h.bucket_bound(i));
    }
    let _ = writeln!(out, "{name}_bucket{{{pre}le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum{plain} {}", h.sum());
    let _ = writeln!(out, "{name}_count{plain} {}", h.count());
}

fn engine_counters(m: &Metrics) -> [(&'static str, &'static str, u64); 3] {
    [
        ("nxfp_requests_total", "requests completed", m.requests),
        ("nxfp_tokens_generated_total", "tokens generated", m.tokens_generated),
        ("nxfp_decode_steps_total", "batched decode steps", m.decode_steps),
    ]
}

fn engine_gauges(m: &Metrics) -> [(&'static str, &'static str, f64); 4] {
    [
        ("nxfp_wall_seconds", "wall time spent stepping", m.wall.as_secs_f64()),
        ("nxfp_kv_bits_packed", "packed KV footprint", m.kv_bits_packed as f64),
        ("nxfp_kv_bits_fp16", "fp16-equivalent KV footprint", m.kv_bits_fp16 as f64),
        ("nxfp_kv_savings", "fp16 bits per packed bit", m.kv_savings()),
    ]
}

/// Prometheus text for a fleet: every metric family is emitted once
/// (HELP/TYPE), carrying the unlabeled rollup series — same names as
/// the single-engine renderer, so existing dashboards read the fleet
/// total unchanged — plus one `{replica="i"}` series per replica.
/// Rollup counters are exact sums; histogram rollups were merged via
/// `Histogram::merge`, with mismatches counted (not silently dropped)
/// in `nxfp_fleet_merge_errors`.
pub fn render_fleet_prometheus(
    m: &Metrics,
    s: &ServingMetrics,
    replicas: &[(&Metrics, &ServingMetrics)],
    merge_errors: &[String],
) -> String {
    let mut out = String::new();
    prom_gauge(&mut out, "nxfp_fleet_replicas", "replicas in this rollup", replicas.len() as f64);
    prom_gauge(
        &mut out,
        "nxfp_fleet_merge_errors",
        "replica histogram rollups skipped for geometry mismatch",
        merge_errors.len() as f64,
    );
    for e in merge_errors {
        // comments are legal exposition text: name the gap next to the gauge
        let _ = writeln!(out, "# merge error: {}", e.replace('\n', " "));
    }
    for (ci, (name, help, v)) in engine_counters(m).into_iter().enumerate() {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
        for (i, (rm, _)) in replicas.iter().enumerate() {
            let rv = engine_counters(rm)[ci].2;
            let _ = writeln!(out, "{name}{{replica=\"{i}\"}} {rv}");
        }
    }
    for (gi, (name, help, v)) in engine_gauges(m).into_iter().enumerate() {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
        for (i, (rm, _)) in replicas.iter().enumerate() {
            let rv = engine_gauges(rm)[gi].2;
            let _ = writeln!(out, "{name}{{replica=\"{i}\"}} {rv}");
        }
    }
    for (ci, (name, help, v)) in serving_counters(s).into_iter().enumerate() {
        let name = format!("nxfp_{name}_total");
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
        for (i, (_, rs)) in replicas.iter().enumerate() {
            let rv = serving_counters(rs)[ci].2;
            let _ = writeln!(out, "{name}{{replica=\"{i}\"}} {rv}");
        }
    }
    for (gi, (name, help, v)) in serving_gauges(s).into_iter().enumerate() {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
        for (i, (_, rs)) in replicas.iter().enumerate() {
            let rv = serving_gauges(rs)[gi].2;
            let _ = writeln!(out, "{name}{{replica=\"{i}\"}} {rv}");
        }
    }
    for (hi, (name, help, h)) in serving_histograms(s).into_iter().enumerate() {
        let name = format!("nxfp_{name}");
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        prom_hist_series(&mut out, &name, h, None);
        for (i, (_, rs)) in replicas.iter().enumerate() {
            prom_hist_series(&mut out, &name, serving_histograms(rs)[hi].2, Some(i));
        }
    }
    out
}

/// The fleet as one JSON object: the rollup and each replica rendered
/// in the single-engine shape (occupancy omitted — probes stay in the
/// per-replica exports), plus the merge-error strings verbatim.
pub fn render_fleet_json(
    m: &Metrics,
    s: &ServingMetrics,
    replicas: &[(&Metrics, &ServingMetrics)],
    merge_errors: &[String],
) -> String {
    let one = |m: &Metrics, s: &ServingMetrics| {
        render_metrics_json(m, s, &[]).trim_end().to_string()
    };
    let mut out = String::from("{");
    let _ = write!(out, "\"replicas\":{},\"merge_errors\":[", replicas.len());
    for (i, e) in merge_errors.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{}\"", esc(e));
    }
    out.push_str("],\"rollup\":");
    out.push_str(&one(m, s));
    out.push_str(",\"per_replica\":[");
    for (i, (rm, rs)) in replicas.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&one(rm, rs));
    }
    out.push_str("]}\n");
    out
}

/// Write a fleet export to `path`, picking the format from the
/// extension exactly like [`write_metrics`].
pub fn write_fleet_metrics(
    path: &Path,
    m: &Metrics,
    s: &ServingMetrics,
    replicas: &[(&Metrics, &ServingMetrics)],
    merge_errors: &[String],
) -> Result<()> {
    let text = if path.extension().is_some_and(|e| e == "json") {
        render_fleet_json(m, s, replicas, merge_errors)
    } else {
        render_fleet_prometheus(m, s, replicas, merge_errors)
    };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, text)
        .with_context(|| format!("writing fleet metrics {}", path.display()))
}

/// Write metrics to `path`, choosing the format from the extension
/// (`.json` → JSON object, anything else → Prometheus text).
pub fn write_metrics(
    path: &Path,
    m: &Metrics,
    s: &ServingMetrics,
    occ: &[CodeOccupancy],
) -> Result<()> {
    let text = if path.extension().is_some_and(|e| e == "json") {
        render_metrics_json(m, s, occ)
    } else {
        render_prometheus(m, s, occ)
    };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, text).with_context(|| format!("writing metrics {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;

    fn sample() -> (Metrics, ServingMetrics, Vec<CodeOccupancy>) {
        let mut m = Metrics::default();
        m.requests = 3;
        m.tokens_generated = 48;
        m.decode_steps = 16;
        m.kv_bits_packed = 1000;
        m.kv_bits_fp16 = 4000;
        let mut s = ServingMetrics::default();
        s.admitted = 3;
        s.retries = 2;
        for v in [0.001, 0.002, 0.010, 0.500] {
            s.latency.record(v);
        }
        s.queue_depth.record(2.0);
        s.spec_accepted = 6;
        s.spec_rejected = 2;
        s.spec_forced = 1;
        s.spec_rollback_rows = 3;
        s.spec_rounds = 3;
        s.spec_accept.record(0.75);
        let mut occ = CodeOccupancy::new(&NxConfig::nxfp(4));
        occ.counts[0] = 10;
        occ.counts[3] = 5;
        occ.counts[8] = 1;
        occ.total = 16;
        occ.clipped = 2;
        (m, s, vec![occ])
    }

    #[test]
    fn prometheus_buckets_are_cumulative_and_terminated() {
        let (m, s, occ) = sample();
        let text = render_prometheus(&m, &s, &occ);
        assert!(text.contains("# TYPE nxfp_latency_seconds histogram"));
        assert!(text.contains("# TYPE nxfp_admitted_total counter"));
        assert!(text.contains("nxfp_admitted_total 3"));
        // cumulative bucket counts are non-decreasing and end at count
        let mut last = 0u64;
        let mut inf = None;
        for line in text.lines().filter(|l| l.starts_with("nxfp_latency_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "cumulative buckets must be non-decreasing: {line}");
            last = v;
            if line.contains("le=\"+Inf\"") {
                inf = Some(v);
            }
        }
        assert_eq!(inf, Some(4), "+Inf bucket must equal count");
        assert!(text.contains("nxfp_latency_seconds_count 4"));
        assert!(text.contains("nxfp_latency_seconds_sum"));
        assert!(text.contains("nxfp_occupancy_clip_rate{config=\"NxFP4"));
    }

    #[test]
    fn bucket_bounds_cover_recorded_values() {
        let (m, s, occ) = sample();
        let text = render_prometheus(&m, &s, &occ);
        // every emitted le bound parses as a positive float
        for line in text.lines().filter(|l| l.contains("_bucket{le=\"") && !l.contains("+Inf")) {
            let bound = line.split("le=\"").nth(1).unwrap().split('"').next().unwrap();
            let b: f64 = bound.parse().unwrap();
            assert!(b > 0.0);
        }
    }

    #[test]
    fn json_renderer_carries_counters_histograms_and_probes() {
        let (m, s, occ) = sample();
        let text = render_metrics_json(&m, &s, &occ);
        assert!(text.starts_with('{') && text.trim_end().ends_with('}'));
        assert!(text.contains("\"admitted\":3"));
        assert!(text.contains("\"retries\":2"));
        assert!(text.contains("\"latency_seconds\":{\"count\":4"));
        assert!(text.contains("\"occupancy\":[{\"config\":\"NxFP4"));
        assert!(text.contains("\"clip_rate\":0.125"));
        // config names with parens/spaces must be escaped-safe
        assert!(!text.contains("\n{"), "single JSON object expected");
    }

    #[test]
    fn spec_accept_rate_surfaces_in_both_renderers_and_fleet() {
        let (m, s, occ) = sample();
        let prom = render_prometheus(&m, &s, &occ);
        assert!(prom.contains("# TYPE nxfp_spec_accept_rate gauge"));
        assert!(prom.contains("nxfp_spec_accept_rate 0.75"));
        assert!(prom.contains("nxfp_spec_accepted_total 6"));
        assert!(prom.contains("nxfp_spec_rounds_total 3"));
        assert!(prom.contains("# TYPE nxfp_spec_accept histogram"));
        let json = render_metrics_json(&m, &s, &occ);
        assert!(json.contains("\"spec_accept_rate\":0.75"));
        assert!(json.contains("\"spec_accepted\":6"));
        assert!(json.contains("\"spec_rollback_rows\":3"));
        assert!(json.contains("\"spec_accept\":{\"count\":1"));
        // fleet: rollup rate derives from summed counters, replicas labeled
        let s1 = ServingMetrics::default();
        let m1 = Metrics::default();
        let mut roll = s.clone();
        roll.merge(&s1).unwrap();
        let reps: Vec<(&Metrics, &ServingMetrics)> = vec![(&m, &s), (&m1, &s1)];
        let fleet = render_fleet_prometheus(&m, &roll, &reps, &[]);
        assert!(fleet.contains("nxfp_spec_accept_rate 0.75"));
        assert!(fleet.contains("nxfp_spec_accept_rate{replica=\"0\"} 0.75"));
        assert!(fleet.contains("nxfp_spec_accept_rate{replica=\"1\"} 0"));
        assert!(fleet.contains("nxfp_spec_accepted_total{replica=\"0\"} 6"));
        let fjson = render_fleet_json(&m, &roll, &reps, &[]);
        assert!(fjson.contains("\"spec_accept_rate\":0.75"));
    }

    #[test]
    fn fleet_prometheus_labels_replicas_and_sums_rollup() {
        let (m0, s0, _) = sample();
        let mut m1 = Metrics::default();
        m1.requests = 5;
        m1.tokens_generated = 20;
        let mut s1 = ServingMetrics::default();
        s1.admitted = 5;
        s1.latency.record(0.250);
        // rollup the way the fleet does
        let mut m = m0;
        m.merge(&m1);
        let mut s = s0.clone();
        s.merge(&s1).unwrap();
        let reps: Vec<(&Metrics, &ServingMetrics)> = vec![(&m0, &s0), (&m1, &s1)];
        let text = render_fleet_prometheus(&m, &s, &reps, &[]);
        // unlabeled rollup is the exact sum; per-replica series labeled
        assert!(text.contains("nxfp_requests_total 8"));
        assert!(text.contains("nxfp_requests_total{replica=\"0\"} 3"));
        assert!(text.contains("nxfp_requests_total{replica=\"1\"} 5"));
        assert!(text.contains("nxfp_admitted_total 8"));
        assert!(text.contains("nxfp_admitted_total{replica=\"1\"} 5"));
        assert!(text.contains("nxfp_latency_seconds_count 5"));
        assert!(text.contains("nxfp_latency_seconds_count{replica=\"0\"} 4"));
        assert!(text.contains("nxfp_latency_seconds_bucket{replica=\"1\",le="));
        assert!(text.contains("nxfp_fleet_replicas 2"));
        // one HELP per family even with three series under it
        let helps = text.matches("# HELP nxfp_admitted_total").count();
        assert_eq!(helps, 1);
        // a merge error surfaces as a gauge + comment, not a panic
        let text = render_fleet_prometheus(&m, &s, &reps, &["replica 1: latency".into()]);
        assert!(text.contains("nxfp_fleet_merge_errors 1"));
        assert!(text.contains("# merge error: replica 1: latency"));
    }

    #[test]
    fn fleet_json_nests_rollup_and_replicas() {
        let (m0, s0, _) = sample();
        let reps: Vec<(&Metrics, &ServingMetrics)> = vec![(&m0, &s0)];
        let text = render_fleet_json(&m0, &s0, &reps, &["replica 0: ttft \"odd\"".into()]);
        assert!(text.starts_with("{\"replicas\":1"));
        assert!(text.contains("\"merge_errors\":[\"replica 0: ttft \\\"odd\\\"\"]"));
        assert!(text.contains("\"rollup\":{\"requests\":3"));
        assert!(text.contains("\"per_replica\":[{\"requests\":3"));
        assert!(text.trim_end().ends_with("]}"));
    }

    #[test]
    fn write_fleet_metrics_picks_format_from_extension() {
        let (m, s, _) = sample();
        let reps: Vec<(&Metrics, &ServingMetrics)> = vec![(&m, &s)];
        let dir = std::env::temp_dir().join(format!("nxfp-fleet-export-{}", std::process::id()));
        let prom = dir.join("fleet.prom");
        let json = dir.join("fleet.json");
        write_fleet_metrics(&prom, &m, &s, &reps, &[]).unwrap();
        write_fleet_metrics(&json, &m, &s, &reps, &[]).unwrap();
        let p = std::fs::read_to_string(&prom).unwrap();
        let j = std::fs::read_to_string(&json).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(p.contains("nxfp_fleet_replicas 1"));
        assert!(j.starts_with("{\"replicas\":1"));
    }

    #[test]
    fn write_metrics_picks_format_from_extension() {
        let (m, s, occ) = sample();
        let dir = std::env::temp_dir().join(format!("nxfp-export-{}", std::process::id()));
        let prom = dir.join("metrics.prom");
        let json = dir.join("metrics.json");
        write_metrics(&prom, &m, &s, &occ).unwrap();
        write_metrics(&json, &m, &s, &occ).unwrap();
        let p = std::fs::read_to_string(&prom).unwrap();
        let j = std::fs::read_to_string(&json).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(p.contains("# TYPE"));
        assert!(j.starts_with('{'));
    }
}
