//! Metrics export: Prometheus-text and JSON renderers over the engine's
//! `Metrics` / `ServingMetrics`, plus the live [`CodeOccupancy`] probes.
//!
//! The Prometheus renderer emits the standard text exposition format:
//! counters as `nxfp_*_total`, gauges bare, histograms as cumulative
//! `_bucket{le="..."}` series over the log-spaced bucket geometry
//! `Histogram` already uses (bound of bucket *i* is `lo·growth^(i+1)`),
//! with zero-count buckets elided — cumulative sums stay valid — and the
//! mandatory `le="+Inf"` / `_sum` / `_count` terminators. The JSON
//! renderer carries the same counters plus per-histogram summaries
//! (count/sum/mean/p50/p95/min/max); both are hand-rolled like the rest
//! of the repo's JSON (no serde).
//!
//! [`write_metrics`] picks the renderer from the file extension
//! (`.json` → JSON, anything else → Prometheus text), so
//! `--metrics-out metrics.prom` and `--metrics-out metrics.json` both
//! do the obvious thing.

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

use crate::coordinator::metrics::{Histogram, ServingMetrics};
use crate::coordinator::Metrics;
use crate::obs::occupancy::CodeOccupancy;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

fn prom_counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn prom_gauge(out: &mut String, name: &str, help: &str, v: f64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn prom_histogram(out: &mut String, name: &str, help: &str, h: &Histogram) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    // zero-count buckets add nothing to the cumulative sum, so eliding
    // them keeps the series exact while keeping 100+-bucket histograms
    // readable
    let mut cum = 0u64;
    for (i, &c) in h.bucket_counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let _ = writeln!(out, "{name}_bucket{{le=\"{:.6e}\"}} {cum}", h.bucket_bound(i));
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

fn serving_counters(s: &ServingMetrics) -> [(&'static str, &'static str, u64); 13] {
    [
        ("admitted", "requests admitted into the batch", s.admitted),
        ("promoted", "admissions via the anti-starvation rule", s.promoted),
        ("rejected", "requests rejected by validation", s.rejected),
        ("prefix_hits", "admissions that adopted cached prefix rows", s.prefix_hits),
        ("prefix_misses", "admissions with no usable cached prefix", s.prefix_misses),
        ("step_faults", "transient faults during batched decode steps", s.step_faults),
        ("chunk_faults", "transient faults during prefill chunks", s.chunk_faults),
        ("nan_faults", "steps rejected by NaN containment", s.nan_faults),
        ("retries", "in-place retries of faulted backend calls", s.retries),
        ("requeued", "slots retired to the queue front after faults", s.requeued),
        ("backend_failed", "requests failed after exhausting retries", s.backend_failed),
        ("shed", "requests dropped by overload policy", s.shed),
        ("deadline_expired", "requests dropped by deadline enforcement", s.deadline_expired),
    ]
}

fn serving_histograms(s: &ServingMetrics) -> [(&'static str, &'static str, &Histogram); 10] {
    [
        ("latency_seconds", "end-to-end request latency", &s.latency),
        ("ttft_seconds", "time to first generated token", &s.ttft),
        ("wait_steps", "scheduler steps spent queued before admission", &s.wait_steps),
        ("queue_depth", "admission queue depth sampled per step", &s.queue_depth),
        ("prefill_chunk_tokens", "prompt tokens fed per prefill chunk", &s.prefill_chunk),
        ("step_prefill_tokens", "prompt tokens fed per engine step", &s.step_prefill_tokens),
        ("step_decode_tokens", "tokens decoded per engine step", &s.step_decode_tokens),
        ("prefix_rows_adopted", "cached prefix rows adopted per hit", &s.prefix_rows),
        ("shared_pages", "KV pages shared via prefix COW, per step", &s.shared_pages),
        ("retry_backoff_seconds", "backoff slept before each retry", &s.retry_backoff),
    ]
}

/// Render the Prometheus text exposition for one engine's metrics.
pub fn render_prometheus(m: &Metrics, s: &ServingMetrics, occ: &[CodeOccupancy]) -> String {
    let mut out = String::new();
    prom_counter(&mut out, "nxfp_requests_total", "requests completed", m.requests);
    prom_counter(&mut out, "nxfp_tokens_generated_total", "tokens generated", m.tokens_generated);
    prom_counter(&mut out, "nxfp_decode_steps_total", "batched decode steps", m.decode_steps);
    prom_gauge(&mut out, "nxfp_wall_seconds", "wall time spent stepping", m.wall.as_secs_f64());
    prom_gauge(&mut out, "nxfp_tokens_per_sec", "decode throughput", m.tokens_per_sec());
    prom_gauge(&mut out, "nxfp_kv_bits_packed", "packed KV footprint", m.kv_bits_packed as f64);
    prom_gauge(
        &mut out,
        "nxfp_kv_bits_fp16",
        "fp16-equivalent KV footprint",
        m.kv_bits_fp16 as f64,
    );
    prom_gauge(&mut out, "nxfp_kv_savings", "fp16 bits per packed bit", m.kv_savings());
    for (name, help, v) in serving_counters(s) {
        prom_counter(&mut out, &format!("nxfp_{name}_total"), help, v);
    }
    for (name, help, h) in serving_histograms(s) {
        prom_histogram(&mut out, &format!("nxfp_{name}"), help, h);
    }
    for o in occ {
        let label = format!("{{config=\"{}\"}}", esc(&o.config));
        let _ = writeln!(out, "# TYPE nxfp_occupancy_elements_total counter");
        let _ = writeln!(out, "nxfp_occupancy_elements_total{label} {}", o.total);
        let _ = writeln!(out, "# TYPE nxfp_occupancy_clipped_total counter");
        let _ = writeln!(out, "nxfp_occupancy_clipped_total{label} {}", o.clipped);
        let _ = writeln!(out, "# TYPE nxfp_occupancy_clip_rate gauge");
        let _ = writeln!(out, "nxfp_occupancy_clip_rate{label} {}", o.clip_rate());
        let _ = writeln!(out, "# TYPE nxfp_occupancy_vacant_fraction gauge");
        let _ = writeln!(out, "nxfp_occupancy_vacant_fraction{label} {}", o.vacant_fraction());
        let _ = writeln!(out, "# TYPE nxfp_occupancy_recycle_rate gauge");
        let _ = writeln!(out, "nxfp_occupancy_recycle_rate{label} {}", o.recycle_rate());
    }
    out
}

fn json_hist(out: &mut String, name: &str, h: &Histogram) {
    let _ = write!(
        out,
        "\"{name}\":{{\"count\":{},\"sum\":{},\"mean\":{},\"p50\":{},\"p95\":{},\
         \"min\":{},\"max\":{}}}",
        h.count(),
        h.sum(),
        h.mean(),
        h.p50(),
        h.p95(),
        h.min(),
        h.max()
    );
}

/// Render the same metrics as one JSON object.
pub fn render_metrics_json(m: &Metrics, s: &ServingMetrics, occ: &[CodeOccupancy]) -> String {
    let mut out = String::from("{");
    let _ = write!(
        out,
        "\"requests\":{},\"tokens_generated\":{},\"decode_steps\":{},\"wall_seconds\":{},\
         \"tokens_per_sec\":{},\"kv_bits_packed\":{},\"kv_bits_fp16\":{},\"kv_savings\":{}",
        m.requests,
        m.tokens_generated,
        m.decode_steps,
        m.wall.as_secs_f64(),
        m.tokens_per_sec(),
        m.kv_bits_packed,
        m.kv_bits_fp16,
        m.kv_savings()
    );
    out.push_str(",\"serving\":{");
    let mut first = true;
    for (name, _, v) in serving_counters(s) {
        if !first {
            out.push(',');
        }
        first = false;
        let _ = write!(out, "\"{name}\":{v}");
    }
    for (name, _, h) in serving_histograms(s) {
        out.push(',');
        json_hist(&mut out, name, h);
    }
    out.push_str("},\"occupancy\":[");
    for (i, o) in occ.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"config\":\"{}\",\"bits\":{},\"total\":{},\"clipped\":{},\"clip_rate\":{},\
             \"vacant_fraction\":{},\"recycle_rate\":{}}}",
            esc(&o.config),
            o.bits,
            o.total,
            o.clipped,
            o.clip_rate(),
            o.vacant_fraction(),
            o.recycle_rate()
        );
    }
    out.push_str("]}\n");
    out
}

/// Write metrics to `path`, choosing the format from the extension
/// (`.json` → JSON object, anything else → Prometheus text).
pub fn write_metrics(
    path: &Path,
    m: &Metrics,
    s: &ServingMetrics,
    occ: &[CodeOccupancy],
) -> Result<()> {
    let text = if path.extension().is_some_and(|e| e == "json") {
        render_metrics_json(m, s, occ)
    } else {
        render_prometheus(m, s, occ)
    };
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, text).with_context(|| format!("writing metrics {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;

    fn sample() -> (Metrics, ServingMetrics, Vec<CodeOccupancy>) {
        let mut m = Metrics::default();
        m.requests = 3;
        m.tokens_generated = 48;
        m.decode_steps = 16;
        m.kv_bits_packed = 1000;
        m.kv_bits_fp16 = 4000;
        let mut s = ServingMetrics::default();
        s.admitted = 3;
        s.retries = 2;
        for v in [0.001, 0.002, 0.010, 0.500] {
            s.latency.record(v);
        }
        s.queue_depth.record(2.0);
        let mut occ = CodeOccupancy::new(&NxConfig::nxfp(4));
        occ.counts[0] = 10;
        occ.counts[3] = 5;
        occ.counts[8] = 1;
        occ.total = 16;
        occ.clipped = 2;
        (m, s, vec![occ])
    }

    #[test]
    fn prometheus_buckets_are_cumulative_and_terminated() {
        let (m, s, occ) = sample();
        let text = render_prometheus(&m, &s, &occ);
        assert!(text.contains("# TYPE nxfp_latency_seconds histogram"));
        assert!(text.contains("# TYPE nxfp_admitted_total counter"));
        assert!(text.contains("nxfp_admitted_total 3"));
        // cumulative bucket counts are non-decreasing and end at count
        let mut last = 0u64;
        let mut inf = None;
        for line in text.lines().filter(|l| l.starts_with("nxfp_latency_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "cumulative buckets must be non-decreasing: {line}");
            last = v;
            if line.contains("le=\"+Inf\"") {
                inf = Some(v);
            }
        }
        assert_eq!(inf, Some(4), "+Inf bucket must equal count");
        assert!(text.contains("nxfp_latency_seconds_count 4"));
        assert!(text.contains("nxfp_latency_seconds_sum"));
        assert!(text.contains("nxfp_occupancy_clip_rate{config=\"NxFP4"));
    }

    #[test]
    fn bucket_bounds_cover_recorded_values() {
        let (m, s, occ) = sample();
        let text = render_prometheus(&m, &s, &occ);
        // every emitted le bound parses as a positive float
        for line in text.lines().filter(|l| l.contains("_bucket{le=\"") && !l.contains("+Inf")) {
            let bound = line.split("le=\"").nth(1).unwrap().split('"').next().unwrap();
            let b: f64 = bound.parse().unwrap();
            assert!(b > 0.0);
        }
    }

    #[test]
    fn json_renderer_carries_counters_histograms_and_probes() {
        let (m, s, occ) = sample();
        let text = render_metrics_json(&m, &s, &occ);
        assert!(text.starts_with('{') && text.trim_end().ends_with('}'));
        assert!(text.contains("\"admitted\":3"));
        assert!(text.contains("\"retries\":2"));
        assert!(text.contains("\"latency_seconds\":{\"count\":4"));
        assert!(text.contains("\"occupancy\":[{\"config\":\"NxFP4"));
        assert!(text.contains("\"clip_rate\":0.125"));
        // config names with parens/spaces must be escaped-safe
        assert!(!text.contains("\n{"), "single JSON object expected");
    }

    #[test]
    fn write_metrics_picks_format_from_extension() {
        let (m, s, occ) = sample();
        let dir = std::env::temp_dir().join(format!("nxfp-export-{}", std::process::id()));
        let prom = dir.join("metrics.prom");
        let json = dir.join("metrics.json");
        write_metrics(&prom, &m, &s, &occ).unwrap();
        write_metrics(&json, &m, &s, &occ).unwrap();
        let p = std::fs::read_to_string(&prom).unwrap();
        let j = std::fs::read_to_string(&json).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert!(p.contains("# TYPE"));
        assert!(j.starts_with('{'));
    }
}
