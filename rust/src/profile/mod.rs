//! Weight-distribution profiling (paper §3, Fig. 3): the distribution of
//! weights after scaling by the block's shared exponent, and the three
//! low-bit MxFP pathologies the paper identifies — outliers the top level
//! cannot track, vacant quantization levels, and the wasted −0 code.

use crate::formats::{shared_exponent, BlockFormat, NxConfig};
use crate::tensor::stats::Histogram;
use crate::tensor::Tensor2;
use crate::util::exp2i;

/// Profile of one tensor in the scaled element domain.
#[derive(Clone, Debug)]
pub struct ScaledProfile {
    /// Histogram of `v / 2^(E_shared + offset)` over all blocks.
    pub hist: Histogram,
    /// Fraction of elements whose scaled magnitude exceeds the top level
    /// (the "inaccurate outlier tracking" mass; paper: values in (6, 8)).
    pub above_top: f64,
    /// Fraction of elements falling in the vacant gap between the top two
    /// levels' midpoint region (paper: (4+6)/2-ish band around 5).
    pub vacant_band: f64,
    /// Fraction of elements that quantize to the zero level (where the
    /// wasted −0 code hurts most).
    pub near_zero: f64,
    pub n: u64,
}

/// Scale every block of `t` by its shared exponent (per the format's offset)
/// and histogram the scaled values, mirroring Fig. 3's x-axis.
pub fn profile_scaled(t: &Tensor2, cfg: &NxConfig) -> ScaledProfile {
    let bf = match cfg.base {
        crate::formats::BaseFormat::Mx => BlockFormat::new(cfg.elem_mx, None),
        crate::formats::BaseFormat::Bfp => {
            BlockFormat::new(crate::formats::ElementFormat::bfp(cfg.bits), None)
        }
    };
    let top = bf.top();
    let range = top * 1.4; // paper plots -8..8 for FP4 (top 6)
    let mut hist = Histogram::new(-range, range, 160);
    let (mut above, mut vacant, mut zeroish, mut n) = (0u64, 0u64, 0u64, 0u64);
    let second = bf.levels[bf.levels.len() - 2];
    let vacant_lo = (top + second) / 2.0 - (top - second) / 4.0;
    let vacant_hi = (top + second) / 2.0 + (top - second) / 4.0;
    let min_pos = bf.levels[1];
    for r in 0..t.rows {
        for block in t.row_blocks(r, cfg.block_size) {
            let Some(e) = shared_exponent(block) else { continue };
            let inv = 1.0 / exp2i(e + bf.offset);
            for &x in block {
                let a = x * inv;
                hist.add(a);
                n += 1;
                let m = a.abs();
                if m > top {
                    above += 1;
                }
                if m > vacant_lo && m < vacant_hi {
                    vacant += 1;
                }
                if m < min_pos / 2.0 {
                    zeroish += 1;
                }
            }
        }
    }
    let nf = n.max(1) as f64;
    ScaledProfile {
        hist,
        above_top: above as f64 / nf,
        vacant_band: vacant as f64 / nf,
        near_zero: zeroish as f64 / nf,
        n,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gaussian_weights_show_the_papers_three_challenges() {
        let mut rng = Rng::seeded(61);
        let t = Tensor2::random_normal(64, 1024, 0.02, &mut rng);
        let p = profile_scaled(&t, &NxConfig::mxfp(4));
        // the block max lands in [4, 8): a visible fraction exceeds top=6
        assert!(p.above_top > 0.001, "above_top={}", p.above_top);
        assert!(p.above_top < 0.2);
        // mass near zero is large for a Gaussian
        assert!(p.near_zero > 0.05);
        // scaled values never exceed 8 = 2^(E+1)/2^(E-2)/... (range bound)
        assert_eq!(p.hist.overflow, 0);
        assert_eq!(p.hist.underflow, 0);
    }

    #[test]
    fn scaled_domain_is_bounded_by_two_to_emax_plus_one() {
        let mut rng = Rng::seeded(62);
        let t = Tensor2::random_normal(8, 256, 3.0, &mut rng);
        let p = profile_scaled(&t, &NxConfig::mxfp(4));
        // |scaled| < 8 for E2M1 (offset -2): max|v| < 2^(E+1) -> v/2^(E-2) < 8.
        // Allow one bin of slack for bins straddling ±8.
        let half_bin = (p.hist.hi - p.hist.lo) / (2.0 * p.hist.counts.len() as f32);
        for (c, &n) in p.hist.centers().iter().zip(&p.hist.counts) {
            if c.abs() > 8.0 + half_bin {
                assert_eq!(n, 0, "mass at {c}");
            }
        }
    }
}
