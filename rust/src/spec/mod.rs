//! Precision-speculative decoding: the quantized model **drafts for
//! itself** (paper §2 direct-cast fidelity, turned into a latency win).
//!
//! NxFP's claim is that direct-cast nxfp4/5 tracks fp16 closely enough to
//! serve from. Speculative decoding makes that claim operational: the
//! low-precision *draft* lane greedily proposes `k` tokens one step at a
//! time, then a *verifier* lane holding the **same checkpoint** at high
//! precision (fp16 or nxfp6) scores all `k` proposals in one batched
//! multi-token call ([`crate::coordinator::StepBackend::verify_chunk`]).
//! The accepted prefix is committed to both lanes, the first rejected
//! position takes the verifier's token, and the draft rolls its packed KV
//! back ([`SlotKv::truncate`]) — the verifier **never** rolls back. There
//! is no separate draft model to train or load: both lanes run the same
//! weights, only the KV precision differs.
//!
//! # Lane pairing
//!
//! A [`SpecEngine`] wraps one [`DecodeEngine`] whose `B`-lane slab is
//! split into `pairs = B / 2` draft lanes (`0..pairs`, scheduled by a
//! [`Scheduler`] built with `lanes_per_request = 2`) and `pairs` verifier
//! lanes (`pairs + p` for pair `p`). The scheduler's paired-lane capacity
//! math guarantees a draft lane is never admitted without its verifier
//! lane. Draft lanes carry the engine's serving `QuantPolicy`; verifier
//! lanes carry [`SpecPolicy::verify`]'s resolution (an independent
//! [`KvPlans`] table — `None` = raw fp16 rows in the slab).
//!
//! # The round invariant
//!
//! With `P` prompt tokens and `g` *confirmed* generations, the last
//! confirmed token sits at output index `F = P + g - 1` and the draft
//! lane holds exactly `F + prov` rows, where `prov` is the number of
//! provisional proposals currently on the output tail (the engine runs
//! with `spec_hold` set, so sampled tokens are pushed but never counted,
//! surfaced, or finished until a verify round judges them). Each round:
//!
//! 1. **Draft** — micro-steps ([`DecodeEngine::step_slots`]) until every
//!    decoding pair holds `target = min(k, max_new - g, S - P - g)`
//!    proposals (pairs already at target are held out of the step).
//! 2. **Verify** — feed the `m + 1` tokens `output[F..=F+m]` at positions
//!    `F..=F+m` through the verifier lane; row `i`'s greedy argmax is the
//!    verifier's token for output index `P + g + i`.
//! 3. **Commit** — accept the longest matching prefix `a`. On a reject
//!    (`a < m`): truncate the output to `P + g + a`, push the verifier's
//!    correction, roll the draft KV back to `F + a + 1` rows, zero the
//!    stale lane tail, and append the `a + 1` verified rows to the
//!    verifier lane. On an all-accept: the verifier's next token rides
//!    along free (the classic bonus token) and the draft adopts the
//!    verifier's row for position `F + m` — backend KV rows are pure
//!    functions of `(token, position, layer)`, so each lane quantizes (or
//!    keeps raw) its own copy of the same row.
//!
//! Greedy sampling makes the construction exact: every confirmed token is
//! either verified-equal to the verifier's argmax or *is* the verifier's
//! argmax, so speculative output is **bit-identical** to verifier-alone
//! greedy decode for every `k` — the fp16-verifier configuration equals
//! plain fp16 serving, and the nxfp6-verifier configuration equals plain
//! nxfp6 serving. A quantized verifier feeds one token per verify call
//! (re-quantizing between tokens); only the raw-lane fp16 verifier may
//! take the whole chunk in one call, because intra-chunk scratch rows are
//! raw by construction.
//!
//! # Acceptance rate as a fidelity probe
//!
//! The acceptance rate of an nxfp4 draft against an fp16 verifier is
//! exactly the online nxfp-vs-fp16 agreement the paper argues for —
//! surfaced per round in `ServingMetrics::spec_accept`, as the
//! `nxfp_spec_accept_rate` gauge in both metrics exporters, and in bench
//! JSON, so the fidelity-vs-format trade becomes a served-traffic
//! observable.

use anyhow::{bail, ensure, Result};
use std::time::Instant;

use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::{
    fault, greedy_argmax, DecodeEngine, GenResponse, Requeue, Slot, SlotKv, SlotState,
};
use crate::formats::QuantPolicy;
use crate::obs::TraceEvent;
use crate::quant::kv_cache::KvPlans;

/// Speculative-decoding policy: how many tokens the draft proposes per
/// round and the precision the verifier lane holds the checkpoint at.
#[derive(Clone, Debug)]
pub struct SpecPolicy {
    /// Proposals per round (`--spec-k`; 1 degenerates to plain decode
    /// with a free bonus token per accepted round).
    pub k: usize,
    /// Verifier-lane KV policy (`--spec-verify`; `fp16` = raw rows, the
    /// reference the paper compares against).
    pub verify: QuantPolicy,
}

impl SpecPolicy {
    pub fn new(k: usize, verify: QuantPolicy) -> Self {
        SpecPolicy { k, verify }
    }

    /// Parse the CLI shape: a draft depth plus a `--spec-verify` policy
    /// spec string (`fp16`, `nxfp6`, or any `selector=format` policy).
    pub fn parse(k: usize, verify: &str) -> Result<Self> {
        Ok(SpecPolicy { k, verify: QuantPolicy::parse(verify)? })
    }
}

/// Verifier-side state for one lane pair: the packed KV mirror (for a
/// quantized verifier; `None` = raw fp16 rows live only in the slab), the
/// verifier lane's row count, and the confirmed-generation counter the
/// round invariant is anchored to.
struct PairState {
    req_id: u64,
    vkv: Option<SlotKv>,
    /// Rows present in the verifier lane (tokens `output[0..vfill]` fed).
    vfill: usize,
    /// Confirmed (verified) generations; `output.len() - P - confirmed`
    /// tokens on the tail are provisional proposals.
    confirmed: usize,
}

/// Draft-then-verify serving loop over a paired-lane [`DecodeEngine`].
///
/// Construction splits the engine's lane pool in half (see the module
/// docs) and flips the engine into `spec_hold` mode; drive it with a
/// scheduler from [`SpecEngine::scheduler`] via
/// [`SpecEngine::serve_continuous`] or [`SpecEngine::step_continuous`].
pub struct SpecEngine {
    engine: DecodeEngine,
    policy: SpecPolicy,
    /// Verifier-lane KV resolution (`None` = raw fp16 rows).
    verify_plans: Option<KvPlans>,
    pairs: usize,
    vstate: Vec<Option<PairState>>,
}

/// Gather rows `n0..n0 + n` of every layer out of a layer-major
/// `[L, total, D]` chunk tensor pair (the verifier commits only the rows
/// of verified tokens; the draft adopts the bonus row).
fn gather_rows(
    k_rows: &[f32],
    v_rows: &[f32],
    l: usize,
    total: usize,
    n0: usize,
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::with_capacity(l * n * d);
    let mut v = Vec::with_capacity(l * n * d);
    for li in 0..l {
        let at = (li * total + n0) * d;
        k.extend_from_slice(&k_rows[at..at + n * d]);
        v.extend_from_slice(&v_rows[at..at + n * d]);
    }
    (k, v)
}

impl SpecEngine {
    /// Wrap `engine` for speculative serving. Fails on a verifier policy
    /// the engine cannot resolve, `k == 0`, or a lane pool too small to
    /// hold one draft/verifier pair.
    pub fn new(engine: DecodeEngine, policy: SpecPolicy) -> Result<Self> {
        ensure!(policy.k >= 1, "--spec-k must be at least 1");
        ensure!(
            engine.max_batch >= 2,
            "speculative decoding needs at least 2 lanes (one draft/verifier pair), got {}",
            engine.max_batch
        );
        let verify_plans = KvPlans::from_policy(&policy.verify, engine.spec.n_layers)?;
        let pairs = engine.max_batch / 2;
        let mut engine = engine;
        engine.spec_hold = true;
        Ok(SpecEngine {
            vstate: (0..pairs).map(|_| None).collect(),
            engine,
            policy,
            verify_plans,
            pairs,
        })
    }

    /// A continuous scheduler shaped for this engine's paired lanes
    /// (`lanes_per_request = 2`: every admission reserves a draft lane
    /// *and* its verifier lane; queue-cap, promotion, and drain all count
    /// pair slots).
    pub fn scheduler(&self, promote_after: u64) -> Scheduler {
        Scheduler::with_lanes_per_request(self.engine.max_batch, promote_after, 2)
    }

    pub fn pairs(&self) -> usize {
        self.pairs
    }

    pub fn policy(&self) -> &SpecPolicy {
        &self.policy
    }

    pub fn engine(&self) -> &DecodeEngine {
        &self.engine
    }

    /// Mutable engine access for serving configuration (trace sinks,
    /// retry policy, deadlines, prefill budget, fault injection).
    pub fn engine_mut(&mut self) -> &mut DecodeEngine {
        &mut self.engine
    }

    /// Unwrap the engine (metrics extraction after a drain).
    pub fn into_engine(self) -> DecodeEngine {
        self.engine
    }

    /// Confirmed generations of pair `p`'s current occupant (0 until its
    /// first verify round).
    fn confirmed(&self, p: usize, sl: &Slot) -> usize {
        match &self.vstate[p] {
            Some(st) if st.req_id == sl.request_id() => st.confirmed,
            _ => 0,
        }
    }

    /// Provisional (unverified) proposals on pair `p`'s output tail.
    fn proposals(&self, p: usize, sl: &Slot) -> usize {
        sl.output().len() - sl.request().prompt.len() - self.confirmed(p, sl)
    }

    /// This round's draft depth for pair `p`: `k` clamped to the
    /// request's remaining token budget and the context window (both at
    /// least 1 for any slot that has not finished).
    fn round_target(&self, p: usize, sl: &Slot) -> usize {
        let pp = sl.request().prompt.len();
        let g = self.confirmed(p, sl);
        let rem = (sl.request().max_new - g).min(self.engine.spec.seq_len - pp - g);
        debug_assert!(rem >= 1, "unfinished slot with no remaining budget");
        self.policy.k.min(rem)
    }

    /// Drop verifier state whose pair lane no longer holds the request it
    /// was built for (finished, expired, faulted, or re-admitted): clear
    /// the [`PairState`] and zero the verifier lane, restoring the
    /// free-lanes-are-zero invariant for the next occupant.
    fn reconcile(&mut self, sched: &Scheduler) {
        for p in 0..self.pairs {
            let keep = match (&self.vstate[p], sched.slots()[p].as_ref()) {
                (Some(st), Some(sl)) => st.req_id == sl.request_id(),
                (Some(_), None) => false,
                (None, _) => true,
            };
            if !keep {
                self.vstate[p] = None;
                self.engine.zero_lane_rows(self.pairs + p, 0);
            }
        }
    }

    /// One speculative serving round: admit, chunk-prefill, draft to
    /// target, verify every drafted pair, and advance the scheduler
    /// clock. One call is one scheduler step — the unit the spec bench's
    /// steps-per-token measurement counts — and may confirm up to `k + 1`
    /// tokens per pair.
    pub fn step_continuous(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        ensure!(
            sched.slots().len() == self.pairs && sched.lanes_per_request() == 2,
            "scheduler shape mismatch: want {} pair slots at 2 lanes each (use \
             SpecEngine::scheduler)",
            self.pairs
        );
        let t0 = Instant::now();
        let mut done = Vec::new();
        let mut requeue = Vec::new();
        self.engine.expire_slots(sched.slots_mut(), &mut done);
        self.engine.admit(sched, &mut done);
        self.reconcile(sched);
        if sched.active() > 0 {
            self.engine.chunk_prefill(sched.slots_mut(), &mut done, &mut requeue, true);
            self.reconcile(sched);
        }
        if sched.active() > 0 {
            self.draft(sched, &mut done, &mut requeue);
            self.verify(sched, &mut done, &mut requeue)?;
            self.reconcile(sched);
        }
        for r in requeue {
            sched.requeue(r);
        }
        if sched.prefix_enabled() {
            let pool = self.engine.page_pool();
            let shared = pool.borrow().shared_pages() as f64;
            self.engine.serving.shared_pages.record(shared);
        }
        let depth = sched.tick();
        self.engine.serving.queue_depth.record(depth as f64);
        self.engine.metrics.wall += t0.elapsed();
        Ok(done)
    }

    /// Drive the paired-lane scheduler until the queue and all pairs
    /// drain.
    pub fn serve_continuous(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        while sched.has_work() {
            out.extend(self.step_continuous(sched)?);
        }
        Ok(out)
    }

    /// Draft phase: engine micro-steps until every decoding pair holds
    /// its round target of proposals. Pairs already at target are lifted
    /// out of the lane pool for the step (their lanes are untouched —
    /// per-slot purity keeps the others bit-identical); prefilling pairs
    /// keep stepping through their prompt and start proposing the moment
    /// prefill finishes. Prefix registration runs after every micro-step
    /// so a freshly decoded prompt is offered to the cache at exactly the
    /// fill the plain engine would have registered it at.
    fn draft(
        &mut self,
        sched: &mut Scheduler,
        done: &mut Vec<GenResponse>,
        requeue: &mut Vec<Requeue>,
    ) {
        loop {
            let mut pending = 0usize;
            let mut held: Vec<(usize, Slot)> = Vec::new();
            for p in 0..self.pairs {
                let at_target = match sched.slots()[p].as_ref() {
                    Some(sl) if sl.state() == SlotState::Decoding => {
                        self.proposals(p, sl) >= self.round_target(p, sl)
                    }
                    Some(_) => false, // still prefilling
                    None => continue,
                };
                if at_target {
                    held.push((p, sched.slots_mut()[p].take().unwrap()));
                } else {
                    pending += 1;
                }
            }
            if pending == 0 {
                for (p, sl) in held {
                    sched.slots_mut()[p] = Some(sl);
                }
                return;
            }
            self.engine.step_slots(sched.slots_mut(), done, requeue, true);
            for (p, sl) in held {
                sched.slots_mut()[p] = Some(sl);
            }
            sched.register_prefixes();
            self.reconcile(sched);
        }
    }

    /// Verify phase: judge every decoding pair's proposals. A verify
    /// fault retires the pair down the same requeue-and-replay ladder as
    /// a step fault; a backend with no native verify path aborts serving
    /// (speculation must never silently degrade to unverified output).
    fn verify(
        &mut self,
        sched: &mut Scheduler,
        done: &mut Vec<GenResponse>,
        requeue: &mut Vec<Requeue>,
    ) -> Result<()> {
        for p in 0..self.pairs {
            let judge = match sched.slots()[p].as_ref() {
                Some(sl) if sl.state() == SlotState::Decoding => self.proposals(p, sl) > 0,
                _ => false,
            };
            if !judge {
                continue;
            }
            let vlane = self.pairs + p;
            let mut sl = sched.slots_mut()[p].take().expect("verify: empty pair lane");
            match self.verify_slot(&mut sl, p, vlane) {
                Ok(Some(true)) => {
                    // confirmed through its budget: retire the pair
                    self.engine.finish_slot(sl, p, done);
                    self.engine.zero_lane_rows(vlane, 0);
                    self.vstate[p] = None;
                }
                Ok(Some(false)) => sched.slots_mut()[p] = Some(sl),
                Ok(None) => {
                    sched.slots_mut()[p] = Some(sl);
                    bail!(
                        "backend has no speculative verify path (verify_chunk returned \
                         None); serve without --spec-k"
                    );
                }
                Err(e) => {
                    let transient = fault::is_transient(&e);
                    sched.slots_mut()[p] = Some(sl);
                    self.engine.retire_faulted(
                        sched.slots_mut(),
                        p,
                        done,
                        requeue,
                        transient,
                        &format!("speculative verify: {e:#}"),
                    );
                    self.vstate[p] = None;
                    self.engine.zero_lane_rows(vlane, 0);
                }
            }
        }
        Ok(())
    }

    /// One verify round for pair `p` (slot taken out of its lane).
    /// Returns `Ok(None)` when the backend has no native verify path,
    /// otherwise `Ok(Some(finished))`.
    fn verify_slot(&mut self, sl: &mut Slot, p: usize, vlane: usize) -> Result<Option<bool>> {
        let (s, d, l, vb) = {
            let sp = &self.engine.spec;
            (sp.seq_len, sp.d_model, sp.n_layers, sp.vocab)
        };
        let id = sl.request_id();
        let pp = sl.request().prompt.len();
        let max_new = sl.request().max_new;

        // first verify round of this occupant: fresh verifier state
        let fresh = !matches!(&self.vstate[p], Some(st) if st.req_id == id);
        if fresh {
            self.engine.zero_lane_rows(vlane, 0);
            let pool = self.engine.page_pool();
            let vkv = self
                .verify_plans
                .as_ref()
                .map(|plans| SlotKv::from_plans_in(plans, d, s, pool));
            self.vstate[p] = Some(PairState { req_id: id, vkv, vfill: 0, confirmed: 0 });
        }
        let g = self.vstate[p].as_ref().unwrap().confirmed;
        let f = pp + g - 1; // feed position of the last confirmed token
        let m = sl.output().len() - pp - g; // proposals to judge
        let rem = (max_new - g).min(s - pp - g);
        debug_assert!(m >= 1 && m <= rem, "verify round with {m} proposals (budget {rem})");
        debug_assert_eq!(sl.fill_rows(), f + m, "draft fill out of sync with proposals");

        // catch-up: the verifier lane needs rows 0..f (tokens output[0..f])
        let vfill = self.vstate[p].as_ref().unwrap().vfill;
        if vfill < f && !self.catch_up(sl, p, vlane, vfill, f)? {
            return Ok(None);
        }

        self.engine.trace_event(Some(id), TraceEvent::Draft { k: m });
        let toks: Vec<i32> = sl.output()[f..].to_vec(); // last confirmed + m proposals

        // judge: a = accepted prefix length; y = the verifier's token for
        // output index P + g + a (correction on a reject, bonus on an
        // all-accept); bonus_rows = the verifier's KV row for position
        // f + m, which the draft adopts when the bonus token is taken
        let (a, y, bonus_rows) = if self.verify_plans.is_none() {
            // raw verifier lane: one batched call scores every proposal;
            // intra-chunk tokens see each other's raw scratch rows,
            // exactly like the baseline per-token schedule
            let Some(v) = self.engine.verify_with_retry(&toks, f, vlane)? else {
                return Ok(None);
            };
            let mut a = 0usize;
            while a < m
                && sl.output()[pp + g + a] == greedy_argmax(&v.logits[a * vb..(a + 1) * vb])
            {
                a += 1;
            }
            let y = greedy_argmax(&v.logits[a * vb..(a + 1) * vb]);
            let (ka, va) = gather_rows(&v.kv.k_rows, &v.kv.v_rows, l, m + 1, 0, a + 1, d);
            self.commit_verifier_rows(p, vlane, f, a + 1, &ka, &va);
            let bonus =
                (a == m).then(|| gather_rows(&v.kv.k_rows, &v.kv.v_rows, l, m + 1, m, 1, d));
            (a, y, bonus)
        } else {
            // quantized verifier lane: intra-chunk raw rows would diverge
            // from verifier-alone quantized decode, so feed one token per
            // call and re-quantize (append + resync) between tokens
            let mut a = 0usize;
            let mut y;
            let mut bonus = None;
            loop {
                let Some(v) = self.engine.verify_with_retry(&toks[a..a + 1], f + a, vlane)?
                else {
                    return Ok(None);
                };
                y = greedy_argmax(&v.logits[..vb]);
                self.commit_verifier_rows(p, vlane, f + a, 1, &v.kv.k_rows, &v.kv.v_rows);
                if a == m {
                    bonus = Some((v.kv.k_rows, v.kv.v_rows));
                    break;
                }
                if sl.output()[pp + g + a] != y {
                    break; // y is the correction for index P + g + a
                }
                a += 1;
            }
            (a, y, bonus)
        };

        // commit the verdict
        let emitted;
        if a < m {
            // reject: drop the divergent tail, take the verifier's token
            let keep = f + a + 1; // draft rows for tokens output[0..=f+a]
            let rolled = sl.fill_rows() - keep; // = m - a - 1
            let out = sl.output_mut();
            out.truncate(pp + g + a);
            out.push(y);
            if let Some(kv) = sl.kv_mut() {
                kv.truncate(keep);
            }
            sl.set_fill(keep);
            self.engine.zero_lane_rows(p, keep);
            emitted = a + 1;
            self.engine.serving.spec_accepted += a as u64;
            self.engine.serving.spec_rejected += 1;
            self.engine.serving.spec_rollback_rows += rolled as u64;
            self.engine.trace_event(Some(id), TraceEvent::Verify { accepted: a });
            self.engine.trace_event(Some(id), TraceEvent::Rollback { rows: rolled });
        } else if m < rem {
            // all accepted: the verifier's next token rides along free and
            // the draft adopts the verifier's row for position f + m
            sl.output_mut().push(y);
            let (bk, bv) = bonus_rows.expect("all-accept without a bonus row");
            if let Some(kv) = sl.kv_mut() {
                kv.append_chunk(1, &bk, &bv);
            } else {
                self.engine.write_lane_rows(p, f + m, 1, &bk, &bv);
            }
            sl.set_fill(f + m + 1);
            emitted = m + 1;
            self.engine.serving.spec_accepted += m as u64;
            self.engine.serving.spec_forced += 1;
            self.engine.trace_event(Some(id), TraceEvent::Verify { accepted: m });
        } else {
            // all accepted at the exact token/context budget: the bonus
            // token would overshoot — plain greedy decode stops at
            // exactly rem tokens, so drop it
            emitted = m;
            self.engine.serving.spec_accepted += m as u64;
            self.engine.trace_event(Some(id), TraceEvent::Verify { accepted: m });
        }

        self.engine.serving.spec_rounds += 1;
        self.engine.serving.spec_accept.record(a as f64 / m as f64);
        self.engine.metrics.tokens_generated += emitted as u64;
        if g == 0 {
            // first *confirmed* token: TTFT is deferred past drafting
            self.engine.serving.ttft.record(sl.arrival().elapsed().as_secs_f64());
        }
        let st = self.vstate[p].as_mut().unwrap();
        st.confirmed = g + emitted;
        let g2 = g + emitted;
        Ok(Some(g2 >= max_new || pp + g2 >= s))
    }

    /// Bring the verifier lane up to the draft's confirmed history: rows
    /// `from..to` (tokens `output[from..to]`), preferring the backend's
    /// native multi-token prefill path (chunks carry no logits — catch-up
    /// never samples) and falling back to single-token verify calls when
    /// there is none. Returns `false` if the backend has neither path.
    fn catch_up(
        &mut self,
        sl: &Slot,
        p: usize,
        vlane: usize,
        from: usize,
        to: usize,
    ) -> Result<bool> {
        let toks: Vec<i32> = sl.output()[from..to].to_vec();
        let n = toks.len();
        if let Some(ck) = self.engine.chunk_with_retry(&toks, from, vlane)? {
            self.commit_verifier_rows(p, vlane, from, n, &ck.k_rows, &ck.v_rows);
            return Ok(true);
        }
        for (i, t) in toks.iter().enumerate() {
            let Some(v) = self.engine.verify_with_retry(&[*t], from + i, vlane)? else {
                return Ok(false);
            };
            self.commit_verifier_rows(p, vlane, from + i, 1, &v.kv.k_rows, &v.kv.v_rows);
        }
        Ok(true)
    }

    /// Land `n` verified rows (layer-major `[L, n, D]`, starting at row
    /// `pos0`) in pair `p`'s verifier lane: quantize-append + resync for a
    /// packed verifier, raw slab write for the fp16 one. Advances `vfill`.
    fn commit_verifier_rows(
        &mut self,
        p: usize,
        vlane: usize,
        pos0: usize,
        n: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        let mut st = self.vstate[p].take().expect("verifier rows without pair state");
        debug_assert_eq!(st.vfill, pos0, "verifier rows must append at the fill");
        match st.vkv.as_mut() {
            Some(vkv) => {
                vkv.append_chunk(n, k_rows, v_rows);
                let (k_lane, v_lane) = self.engine.lane_mut(vlane);
                vkv.sync_into(k_lane, v_lane);
            }
            None => self.engine.write_lane_rows(vlane, pos0, n, k_rows, v_rows),
        }
        st.vfill = pos0 + n;
        self.vstate[p] = Some(st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{DecodeEngine, GenRequest, SynthBackend};
    use crate::models::LmSpec;

    fn reqs() -> Vec<GenRequest> {
        vec![
            GenRequest { id: 1, prompt: vec![3, 9, 4], max_new: 8 },
            GenRequest { id: 2, prompt: vec![7, 1], max_new: 64 }, // context-capped
            GenRequest { id: 3, prompt: vec![5, 2, 8, 2, 8, 1], max_new: 4 },
        ]
    }

    fn plain_reference(kv: &QuantPolicy) -> Vec<(u64, Vec<i32>)> {
        let spec = LmSpec::tiny();
        let mut eng = DecodeEngine::with_backend(
            spec,
            Box::new(SynthBackend::new(&spec)),
            kv,
            2,
        );
        let mut sched = Scheduler::new(2, 8);
        for r in reqs() {
            assert!(sched.enqueue(r).is_none());
        }
        let mut out: Vec<(u64, Vec<i32>)> = eng
            .serve_continuous(&mut sched)
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        out.sort();
        out
    }

    fn spec_run(draft: &str, verify: &str, k: usize) -> (Vec<(u64, Vec<i32>)>, DecodeEngine) {
        let spec = LmSpec::tiny();
        let engine = DecodeEngine::with_backend(
            spec,
            Box::new(SynthBackend::new(&spec)),
            &QuantPolicy::parse(draft).unwrap(),
            4,
        );
        let mut se = SpecEngine::new(engine, SpecPolicy::parse(k, verify).unwrap()).unwrap();
        let mut sched = se.scheduler(8);
        for r in reqs() {
            assert!(sched.enqueue(r).is_none());
        }
        let mut out: Vec<(u64, Vec<i32>)> = se
            .serve_continuous(&mut sched)
            .unwrap()
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect();
        out.sort();
        (out, se.into_engine())
    }

    #[test]
    fn new_rejects_bad_configs() {
        let spec = LmSpec::tiny();
        let eng = DecodeEngine::with_backend(
            spec,
            Box::new(SynthBackend::new(&spec)),
            &QuantPolicy::fp16(),
            1,
        );
        assert!(SpecEngine::new(eng, SpecPolicy::parse(4, "fp16").unwrap()).is_err());
        let eng = DecodeEngine::with_backend(
            spec,
            Box::new(SynthBackend::new(&spec)),
            &QuantPolicy::fp16(),
            4,
        );
        assert!(SpecEngine::new(eng, SpecPolicy::parse(0, "fp16").unwrap()).is_err());
    }

    #[test]
    fn spec_matches_fp16_verifier_alone_and_counters_telescope() {
        let want = plain_reference(&QuantPolicy::fp16());
        let (got, eng) = spec_run("nxfp4", "fp16", 3);
        assert_eq!(got, want, "speculative output diverged from verifier-alone decode");
        let s = &eng.serving;
        assert!(s.spec_rounds > 0);
        assert_eq!(
            s.spec_accepted + s.spec_rejected + s.spec_forced,
            eng.metrics.tokens_generated,
            "accept/reject/bonus counters must telescope to tokens generated"
        );
        assert_eq!(s.spec_accept.count(), s.spec_rounds);
    }

    #[test]
    fn spec_matches_quantized_verifier_alone() {
        // nxfp6 verifier: one token per verify call, re-quantized between
        // — must equal a plain engine serving at nxfp6
        let want = plain_reference(&QuantPolicy::parse("nxfp6").unwrap());
        let (got, eng) = spec_run("nxfp4", "nxfp6", 4);
        assert_eq!(got, want, "quantized-verifier spec diverged from nxfp6-alone decode");
        assert!(eng.serving.spec_rounds > 0);
    }
}
