//! Evaluation harness: held-out perplexity (Wikitext2 stand-in) and 4-way
//! multiple-choice reasoning accuracy (MMLU stand-in), both driven through
//! the AOT artifacts with weights supplied as literals — so a quantized
//! model is evaluated by dequantizing its weights (direct-cast) and feeding
//! the same eval graph.

pub mod perplexity;
pub mod reasoning;

use crate::formats::{EncodePlan, NxConfig};
use crate::models::Checkpoint;
use crate::quant::quantize_matrix_with;

pub use perplexity::{perplexity, Perplexity};
pub use reasoning::reasoning_accuracy;

/// Direct-cast a checkpoint: quantize-dequantize every quantizable weight
/// under `cfg`, leaving embeddings/norm gains in full precision (the paper's
/// weight-only setting). Returns the degraded checkpoint the eval graph sees.
///
/// One [`EncodePlan`] is built for the whole checkpoint and threaded
/// through every per-tensor `quantize_matrix` call — plan construction
/// (threshold bisection over the f32 bit space) is per-config work, not
/// per-tensor work.
pub fn quantize_checkpoint(
    ck: &Checkpoint,
    spec_quantizable: &[String],
    cfg: &NxConfig,
) -> Checkpoint {
    let plan = EncodePlan::new(cfg);
    let mut out = ck.clone();
    for name in spec_quantizable {
        if let Some(t) = out.get_mut(name) {
            *t = quantize_matrix_with(t, cfg, &plan).dequantize(cfg);
        }
    }
    out
}

/// Bit-true footprint of a checkpoint under a quantization config
/// (quantizable weights at `cfg` bits, everything else FP16), in bytes.
pub fn checkpoint_footprint_bytes(
    ck: &Checkpoint,
    spec_quantizable: &[String],
    cfg: Option<&NxConfig>,
) -> u64 {
    let mut bits = 0u64;
    for (name, t) in &ck.params {
        let is_q = spec_quantizable.contains(name);
        bits += match (is_q, cfg) {
            (true, Some(c)) => c.footprint_bits(t.cols) * t.rows as u64,
            _ => (t.len() as u64) * 16,
        };
    }
    bits / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LmSpec;

    #[test]
    fn quantize_checkpoint_touches_only_quantizable() {
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 3);
        let q = quantize_checkpoint(&ck, &spec.quantizable(), &NxConfig::nxfp(4));
        // embeddings untouched
        assert_eq!(q.get("embed").unwrap(), ck.get("embed").unwrap());
        assert_eq!(q.get("l0.ln1").unwrap(), ck.get("l0.ln1").unwrap());
        // weights changed (4-bit is lossy on random init)
        assert_ne!(q.get("l0.wq").unwrap(), ck.get("l0.wq").unwrap());
    }

    #[test]
    fn footprint_shrinks_with_bits() {
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 3);
        let qn = spec.quantizable();
        let fp16 = checkpoint_footprint_bytes(&ck, &qn, None);
        let w4 = checkpoint_footprint_bytes(&ck, &qn, Some(&NxConfig::nxfp(4)));
        let w6 = checkpoint_footprint_bytes(&ck, &qn, Some(&NxConfig::mxfp(6)));
        assert!(w4 < w6 && w6 < fp16);
    }
}
