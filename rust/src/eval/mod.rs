//! Evaluation harness: held-out perplexity (Wikitext2 stand-in) and 4-way
//! multiple-choice reasoning accuracy (MMLU stand-in), both driven through
//! the AOT artifacts with weights supplied as literals — so a quantized
//! model is evaluated by dequantizing its weights (direct-cast) and feeding
//! the same eval graph.

pub mod perplexity;
pub mod reasoning;

use crate::formats::{PlanTable, QuantPolicy, TensorClass};
use crate::models::Checkpoint;
use crate::quant::quantize_matrix_with;

pub use perplexity::{perplexity, Perplexity};
pub use reasoning::reasoning_accuracy;

/// Direct-cast a checkpoint under a [`QuantPolicy`]: quantize-dequantize
/// every quantizable weight through its **resolved** config, leaving
/// FP16-resolved weights (and embeddings/norm gains, which are not in
/// `spec_quantizable`) untouched — the paper's weight-only setting,
/// generalized to mixed precision. Returns the degraded checkpoint the
/// eval graph sees.
///
/// One `EncodePlan` is built per **distinct resolved config** (a shared
/// [`PlanTable`] over the policy's interned configs, so plan construction
/// — threshold bisection over the f32 bit space — happens once per
/// config, not once per tensor). `QuantPolicy::uniform(cfg)` reproduces
/// the legacy single-config path bit for bit
/// (`tests/policy_equivalence.rs`).
pub fn quantize_checkpoint(
    ck: &Checkpoint,
    spec_quantizable: &[String],
    policy: &QuantPolicy,
) -> Checkpoint {
    let mut plans = PlanTable::new(policy);
    let mut out = ck.clone();
    for name in spec_quantizable {
        let Some((cfg, plan)) = plans.resolve(TensorClass::weight(name)) else { continue };
        if let Some(t) = out.get_mut(name) {
            *t = quantize_matrix_with(t, cfg, plan).dequantize(cfg);
        }
    }
    out
}

/// One line of a [`FootprintReport`]: every tensor that resolved to the
/// same class (one quantized config, or FP16).
#[derive(Clone, Debug)]
pub struct ClassFootprint {
    /// Display name of the resolved config (`"FP16"` for unquantized).
    pub label: String,
    pub tensors: usize,
    pub elems: u64,
    /// Bit-true storage cost of this class (per-block metadata included
    /// for quantized configs; 16 bits/element for FP16).
    pub bits: u64,
}

impl ClassFootprint {
    /// Realized bits per element including metadata (the per-class
    /// effective-bits breakdown).
    pub fn effective_bits(&self) -> f64 {
        self.bits as f64 / self.elems.max(1) as f64
    }
}

/// Policy-driven checkpoint footprint: per-class bit totals plus the
/// aggregate, replacing the old single-config byte count.
#[derive(Clone, Debug)]
pub struct FootprintReport {
    /// Quantized classes first (policy config order), FP16 last.
    pub classes: Vec<ClassFootprint>,
}

impl FootprintReport {
    pub fn total_bits(&self) -> u64 {
        self.classes.iter().map(|c| c.bits).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bits() / 8
    }
}

/// Bit-true footprint of a checkpoint under a policy: quantizable weights
/// at their resolved config, everything else (embeddings, norm gains,
/// FP16-resolved weights) at FP16.
pub fn checkpoint_footprint(
    ck: &Checkpoint,
    spec_quantizable: &[String],
    policy: &QuantPolicy,
) -> FootprintReport {
    let n_cfg = policy.configs().len();
    // per config id, plus one trailing FP16 bucket
    let mut classes: Vec<ClassFootprint> = (0..=n_cfg)
        .map(|i| ClassFootprint {
            label: if i < n_cfg { policy.config(i).name() } else { "FP16".to_string() },
            tensors: 0,
            elems: 0,
            bits: 0,
        })
        .collect();
    for (name, t) in &ck.params {
        let resolved = if spec_quantizable.contains(name) {
            policy.resolve_id(TensorClass::weight(name))
        } else {
            None
        };
        let (slot, bits) = match resolved {
            Some(id) => (id, policy.config(id).footprint_bits(t.cols) * t.rows as u64),
            None => (n_cfg, t.len() as u64 * 16),
        };
        classes[slot].tensors += 1;
        classes[slot].elems += t.len() as u64;
        classes[slot].bits += bits;
    }
    classes.retain(|c| c.tensors > 0);
    FootprintReport { classes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;
    use crate::models::LmSpec;

    #[test]
    fn quantize_checkpoint_touches_only_quantizable() {
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 3);
        let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
        let q = quantize_checkpoint(&ck, &spec.quantizable(), &policy);
        // embeddings untouched
        assert_eq!(q.get("embed").unwrap(), ck.get("embed").unwrap());
        assert_eq!(q.get("l0.ln1").unwrap(), ck.get("l0.ln1").unwrap());
        // weights changed (4-bit is lossy on random init)
        assert_ne!(q.get("l0.wq").unwrap(), ck.get("l0.wq").unwrap());
    }

    #[test]
    fn mixed_policy_quantizes_per_class() {
        // layer 0 at 6 bits, the rest at 4: layer-0 weights must match a
        // uniform mxfp6 cast, everything else a uniform nxfp4 cast
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 4);
        let qn = spec.quantizable();
        let mixed = QuantPolicy::parse("layers.0.weights=mxfp6,weights=nxfp4").unwrap();
        let q = quantize_checkpoint(&ck, &qn, &mixed);
        let q6 = quantize_checkpoint(&ck, &qn, &QuantPolicy::uniform(NxConfig::mxfp(6)));
        let q4 = quantize_checkpoint(&ck, &qn, &QuantPolicy::uniform(NxConfig::nxfp(4)));
        assert_eq!(q.get("l0.wq").unwrap(), q6.get("l0.wq").unwrap());
        assert_eq!(q.get("l1.wq").unwrap(), q4.get("l1.wq").unwrap());
        assert_eq!(q.get("unembed").unwrap(), q4.get("unembed").unwrap());
        // fp16 policy is the identity
        let id = quantize_checkpoint(&ck, &qn, &QuantPolicy::fp16());
        assert_eq!(id.get("l0.wq").unwrap(), ck.get("l0.wq").unwrap());
    }

    #[test]
    fn footprint_shrinks_with_bits() {
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 3);
        let qn = spec.quantizable();
        let fp16 = checkpoint_footprint(&ck, &qn, &QuantPolicy::fp16()).total_bytes();
        let w4 = checkpoint_footprint(&ck, &qn, &QuantPolicy::uniform(NxConfig::nxfp(4)))
            .total_bytes();
        let w6 = checkpoint_footprint(&ck, &qn, &QuantPolicy::uniform(NxConfig::mxfp(6)))
            .total_bytes();
        assert!(w4 < w6 && w6 < fp16);
    }

    #[test]
    fn footprint_reports_per_class_effective_bits() {
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 3);
        let qn = spec.quantizable();
        let policy = QuantPolicy::parse("layers.0.weights=mxfp6,weights=nxfp4").unwrap();
        let report = checkpoint_footprint(&ck, &qn, &policy);
        assert_eq!(report.classes.len(), 3); // mxfp6, nxfp4, fp16
        let by = |label: &str| {
            report.classes.iter().find(|c| c.label.contains(label)).unwrap()
        };
        // per-class effective bits match the configs' own accounting
        // exactly: every quantizable tensor's cols are a multiple of the
        // block size here, so no partial-block rounding
        assert!((by("MxFP6").effective_bits() - NxConfig::mxfp(6).effective_bits()).abs() < 1e-9);
        assert!((by("NxFP4").effective_bits() - NxConfig::nxfp(4).effective_bits()).abs() < 1e-9);
        assert_eq!(by("FP16").effective_bits(), 16.0);
        // layer 0 has 6 quantizable mats at 6 bits
        assert_eq!(by("MxFP6").tensors, 6);
        // totals add up
        let sum: u64 = report.classes.iter().map(|c| c.bits).sum();
        assert_eq!(sum, report.total_bits());
    }
}
