//! Multiple-choice reasoning accuracy (MMLU stand-in) through the
//! `score_step` artifact, scored exactly like lm-eval-harness: the choice
//! with the highest continuation log-probability wins.

use anyhow::Result;

use crate::models::corpus::{GrammarSpec, Probe};
use crate::models::Checkpoint;
use crate::runtime::{lit, Step};
use crate::train::params_to_literals;

/// Score probes and return accuracy in [0, 1].
///
/// `score_step` contract: inputs `P` params + `tokens [B, S+1]` (i32);
/// output `nll [B, S]` where `nll[b, i] = -log p(tokens[b, i+1] | tokens[b, ..=i])`.
///
/// Each probe contributes 4 rows (one per choice): `BOS e r choice SEP…pad`.
/// The choice token sits at index 3, so its NLL is `nll[row, 2]`.
pub fn reasoning_accuracy(
    step: &Step,
    ck: &Checkpoint,
    probes: &[Probe],
    seq: usize,
    batch: usize,
) -> Result<f64> {
    assert!(batch % 4 == 0, "batch must pack whole probes (4 rows each)");
    let params = params_to_literals(ck)?;
    let probes_per_batch = batch / 4;
    let mut correct = 0u64;
    let mut total = 0u64;
    for chunk in probes.chunks(probes_per_batch) {
        if chunk.len() < probes_per_batch {
            break;
        }
        let mut toks = Vec::with_capacity(batch * (seq + 1));
        for p in chunk {
            for &choice in &p.choices {
                let mut row = p.prompt.clone();
                row.push(choice);
                row.resize(seq + 1, GrammarSpec::SEP);
                toks.extend_from_slice(&row);
            }
        }
        let tok_lit = lit::from_i32(&toks, &[batch as i64, seq as i64 + 1])?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&tok_lit);
        let out = step.run(&args)?;
        anyhow::ensure!(out.len() == 1, "score_step returned {} outputs", out.len());
        let nll = lit::to_f32(&out[0])?;
        anyhow::ensure!(nll.len() == batch * seq, "nll shape mismatch");
        for (pi, p) in chunk.iter().enumerate() {
            let choice_pos = p.prompt.len() - 1; // nll index of the choice token
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..4 {
                let row = pi * 4 + c;
                let v = nll[row * seq + choice_pos];
                if v < best.0 {
                    best = (v, c);
                }
            }
            if best.1 == p.answer {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}
