//! Held-out perplexity through the `eval_step` artifact.

use anyhow::Result;

use crate::models::{Checkpoint, Corpus};
use crate::runtime::{lit, Step};
use crate::train::params_to_literals;

/// Perplexity result with the raw NLL aggregates.
#[derive(Clone, Copy, Debug)]
pub struct Perplexity {
    pub sum_nll: f64,
    pub tokens: u64,
}

impl Perplexity {
    pub fn ppl(&self) -> f64 {
        (self.sum_nll / self.tokens.max(1) as f64).exp()
    }
}

/// Evaluate a checkpoint's perplexity over the corpus eval split.
///
/// `eval_step` contract: inputs `P` params + `tokens [B, S+1]` (i32);
/// outputs `(sum_nll, count)` f32 scalars. Windows are batched `batch` at a
/// time; a trailing partial batch is dropped (deterministic across formats,
/// so comparisons are apples-to-apples).
pub fn perplexity(
    step: &Step,
    ck: &Checkpoint,
    corpus: &Corpus,
    seq: usize,
    batch: usize,
) -> Result<Perplexity> {
    let params = params_to_literals(ck)?;
    let windows = corpus.eval_windows(seq);
    anyhow::ensure!(
        windows.len() >= batch,
        "eval split too small: {} windows < batch {batch}",
        windows.len()
    );
    let mut agg = Perplexity { sum_nll: 0.0, tokens: 0 };
    for chunk in windows.chunks(batch) {
        if chunk.len() < batch {
            break; // fixed artifact batch shape
        }
        let mut toks = Vec::with_capacity(batch * (seq + 1));
        for w in chunk {
            toks.extend_from_slice(w);
        }
        let tok_lit = lit::from_i32(&toks, &[batch as i64, seq as i64 + 1])?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&tok_lit);
        let out = step.run(&args)?;
        anyhow::ensure!(out.len() == 2, "eval_step returned {} outputs", out.len());
        agg.sum_nll += lit::first_f32(&out[0])? as f64;
        agg.tokens += lit::first_f32(&out[1])? as u64;
    }
    Ok(agg)
}
