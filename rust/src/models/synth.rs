//! Synthetic LLM weight generation.
//!
//! Real checkpoints are unavailable offline, so the quantization-error
//! experiments (Figs. 3 & 8) run on synthetic tensors whose per-block
//! statistics match the paper's own profile of Llama3 / Llama3.1 / Phi3 /
//! Llama2 / Mistral weights: near-Gaussian in the E_shared-scaled domain
//! (range ±8 for FP4), with per-row scale spread and a thin heavy tail of
//! outliers that lands in the (6, 8) band MxFP4 cannot track (paper §3).

use crate::tensor::Tensor2;
use crate::util::rng::Rng;

/// Distribution profile of one named model's weights.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Base weight scale (typical LLM layers: ~1e-2).
    pub sigma: f32,
    /// Log-normal spread of per-row scales (inter-row heterogeneity).
    pub row_spread: f32,
    /// Probability an element is an outlier…
    pub outlier_frac: f32,
    /// …drawn at `outlier_scale × sigma`.
    pub outlier_scale: f32,
    pub seed: u64,
}

impl ModelProfile {
    /// The five models profiled in the paper's Fig. 3 / Fig. 8, with
    /// distribution parameters chosen so each reproduces the paper's scaled
    /// histogram shape (slightly different tail mass per model family).
    #[rustfmt::skip]
    pub fn all() -> Vec<ModelProfile> {
        vec![
            ModelProfile { name: "Llama3-8B",   sigma: 0.016, row_spread: 0.35, outlier_frac: 0.0020, outlier_scale: 4.5, seed: 1003 },
            ModelProfile { name: "Llama3.1-8B", sigma: 0.015, row_spread: 0.35, outlier_frac: 0.0022, outlier_scale: 4.5, seed: 1031 },
            ModelProfile { name: "Phi3-4B",     sigma: 0.020, row_spread: 0.45, outlier_frac: 0.0035, outlier_scale: 5.0, seed: 1004 },
            ModelProfile { name: "Llama2-7B",   sigma: 0.014, row_spread: 0.30, outlier_frac: 0.0015, outlier_scale: 4.0, seed: 1007 },
            ModelProfile { name: "Llama2-13B",  sigma: 0.013, row_spread: 0.28, outlier_frac: 0.0013, outlier_scale: 4.0, seed: 1013 },
            ModelProfile { name: "Mistral-7B",  sigma: 0.014, row_spread: 0.25, outlier_frac: 0.0010, outlier_scale: 3.5, seed: 1077 },
            ModelProfile { name: "Gemma2-2B",   sigma: 0.022, row_spread: 0.50, outlier_frac: 0.0045, outlier_scale: 5.5, seed: 1002 },
        ]
    }

    pub fn by_name(name: &str) -> Option<ModelProfile> {
        Self::all().into_iter().find(|p| p.name == name)
    }
}

/// Generate a weight matrix under a profile.
pub fn synth_weights(profile: &ModelProfile, rows: usize, cols: usize) -> Tensor2 {
    let mut rng = Rng::seeded(profile.seed);
    let mut t = Tensor2::zeros(rows, cols);
    for r in 0..rows {
        // log-normal per-row scale
        let row_scale = (profile.row_spread * rng.normal() as f32).exp();
        let s = profile.sigma * row_scale;
        for v in t.row_mut(r).iter_mut() {
            let mut x = rng.normal_f32(0.0, s);
            if rng.f32() < profile.outlier_frac {
                x *= profile.outlier_scale;
            }
            *v = x;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;
    use crate::profile::profile_scaled;

    #[test]
    fn profiles_are_distinct_and_deterministic() {
        let a = synth_weights(&ModelProfile::by_name("Llama3-8B").unwrap(), 8, 64);
        let b = synth_weights(&ModelProfile::by_name("Llama3-8B").unwrap(), 8, 64);
        assert_eq!(a, b);
        let c = synth_weights(&ModelProfile::by_name("Mistral-7B").unwrap(), 8, 64);
        assert_ne!(a, c);
    }

    #[test]
    fn synthetic_weights_reproduce_fig3_shape() {
        for p in ModelProfile::all() {
            let w = synth_weights(&p, 128, 512);
            let prof = profile_scaled(&w, &NxConfig::mxfp(4));
            // paper Fig. 3: visible mass above the top level (6) but small
            assert!(prof.above_top > 0.0005, "{}: above_top={}", p.name, prof.above_top);
            assert!(prof.above_top < 0.25, "{}: above_top={}", p.name, prof.above_top);
            // near-zero mass dominates (normal distribution)
            assert!(prof.near_zero > 0.03, "{}: near_zero={}", p.name, prof.near_zero);
        }
    }

    #[test]
    fn all_named_models_present() {
        let names: Vec<&str> = ModelProfile::all().iter().map(|p| p.name).collect();
        for want in ["Llama3-8B", "Llama2-7B", "Mistral-7B", "Gemma2-2B"] {
            assert!(names.contains(&want));
        }
    }
}
