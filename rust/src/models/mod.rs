//! Model substrate: named LLM shape profiles (for bit-true footprint
//! accounting), synthetic weight generators matched to the paper's Fig. 3
//! distribution profile, the in-repo transformer LM spec (shared with the
//! JAX side), binary checkpoints, and the synthetic grammar corpus that
//! stands in for Wikitext2 / MMLU (see DESIGN.md §3 Substitutions).

pub mod checkpoint;
pub mod corpus;
pub mod synth;
pub mod transformer;

pub use checkpoint::Checkpoint;
pub use corpus::{Corpus, GrammarSpec, Probe};
pub use synth::{synth_weights, ModelProfile};
pub use transformer::{LmSpec, NamedModel};
