//! Synthetic grammar corpus — the offline stand-in for Wikitext2 (perplexity)
//! and MMLU (reasoning probes). See DESIGN.md §3.
//!
//! The grammar emits "fact" clauses `entity relation value SEP` where
//! `value = fact(entity, relation)` is a fixed deterministic mapping, mixed
//! with Zipf-distributed filler words. A language model must learn both the
//! local syntax (easy; drives perplexity below the unigram bound) and the
//! fact table (hard; probed by the multiple-choice reasoning task, which is
//! scored exactly like lm-eval-harness: argmax of summed continuation
//! log-probability over four candidates).

use crate::util::rng::{Rng, Zipf};

/// Grammar hyperparameters. Token-id layout:
/// `[0]=BOS [1]=SEP | entities | relations | values | fillers`.
#[derive(Clone, Copy, Debug)]
pub struct GrammarSpec {
    pub vocab: usize,
    pub n_entities: usize,
    pub n_relations: usize,
    pub n_values: usize,
    /// probability a clause is a fact (vs a filler run)
    pub fact_prob: f64,
    /// filler run length range
    pub filler_len: (usize, usize),
}

impl GrammarSpec {
    pub fn default_for_vocab(vocab: usize) -> Self {
        assert!(vocab >= 64);
        // reserve ~1/4 of the vocab to each class, rest filler
        let n = vocab / 4;
        GrammarSpec {
            vocab,
            n_entities: n.min(64),
            n_relations: (n / 2).min(32),
            n_values: n.min(96),
            fact_prob: 0.65,
            filler_len: (2, 6),
        }
    }

    pub const BOS: i32 = 0;
    pub const SEP: i32 = 1;

    pub fn entity(&self, i: usize) -> i32 {
        (2 + i % self.n_entities) as i32
    }

    pub fn relation(&self, i: usize) -> i32 {
        (2 + self.n_entities + i % self.n_relations) as i32
    }

    pub fn value(&self, i: usize) -> i32 {
        (2 + self.n_entities + self.n_relations + i % self.n_values) as i32
    }

    pub fn first_filler(&self) -> usize {
        2 + self.n_entities + self.n_relations + self.n_values
    }

    /// The deterministic fact table: value index for (entity, relation).
    pub fn fact(&self, e: usize, r: usize) -> usize {
        (e.wrapping_mul(31) ^ r.wrapping_mul(17)).wrapping_add(e * r) % self.n_values
    }
}

/// A generated token stream split into train/eval.
pub struct Corpus {
    pub spec: GrammarSpec,
    pub train: Vec<i32>,
    pub eval: Vec<i32>,
}

impl Corpus {
    /// Generate `n_train` + `n_eval` tokens with a seeded RNG.
    pub fn generate(spec: GrammarSpec, n_train: usize, n_eval: usize, seed: u64) -> Self {
        let mut rng = Rng::seeded(seed);
        let zipf = Zipf::new(spec.vocab - spec.first_filler(), 1.2);
        let emit = |rng: &mut Rng, out: &mut Vec<i32>, n: usize| {
            out.push(GrammarSpec::BOS);
            while out.len() < n {
                if rng.f64() < spec.fact_prob {
                    let e = rng.below(spec.n_entities);
                    let r = rng.below(spec.n_relations);
                    let v = spec.fact(e, r);
                    out.push(spec.entity(e));
                    out.push(spec.relation(r));
                    out.push(spec.value(v));
                    out.push(GrammarSpec::SEP);
                } else {
                    let len = spec.filler_len.0
                        + rng.below(spec.filler_len.1 - spec.filler_len.0 + 1);
                    for _ in 0..len {
                        out.push((spec.first_filler() + zipf.sample(rng)) as i32);
                    }
                    out.push(GrammarSpec::SEP);
                }
            }
            out.truncate(n);
        };
        let mut train = Vec::with_capacity(n_train);
        let mut eval = Vec::with_capacity(n_eval);
        emit(&mut rng, &mut train, n_train);
        emit(&mut rng, &mut eval, n_eval);
        Corpus { spec, train, eval }
    }

    /// Sample a `(batch, seq+1)` slab of token windows from a split
    /// (`x = [..seq]`, `y = [1..seq+1]` on the consumer side).
    pub fn batch(&self, split: &[i32], batch: usize, seq: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let start = rng.below(split.len() - seq - 1);
            out.extend_from_slice(&split[start..start + seq + 1]);
        }
        out
    }

    /// Deterministic sequential eval windows covering the eval split.
    pub fn eval_windows(&self, seq: usize) -> Vec<Vec<i32>> {
        self.eval
            .chunks(seq + 1)
            .filter(|c| c.len() == seq + 1)
            .map(|c| c.to_vec())
            .collect()
    }
}

/// A 4-way multiple-choice reasoning probe (MMLU stand-in).
#[derive(Clone, Debug)]
pub struct Probe {
    /// Prompt tokens: `BOS … entity relation`.
    pub prompt: Vec<i32>,
    /// Four candidate continuation tokens (single value token each).
    pub choices: [i32; 4],
    /// Index of the grammar-correct choice.
    pub answer: usize,
}

impl Probe {
    /// Generate `n` probes with shuffled distractor values.
    pub fn generate(spec: &GrammarSpec, n: usize, seed: u64) -> Vec<Probe> {
        let mut rng = Rng::seeded(seed);
        (0..n)
            .map(|_| {
                let e = rng.below(spec.n_entities);
                let r = rng.below(spec.n_relations);
                let v = spec.fact(e, r);
                let mut distract = Vec::new();
                while distract.len() < 3 {
                    let d = rng.below(spec.n_values);
                    if d != v && !distract.contains(&d) {
                        distract.push(d);
                    }
                }
                let answer = rng.below(4);
                let mut choices = [0i32; 4];
                let mut di = 0;
                for (i, c) in choices.iter_mut().enumerate() {
                    *c = if i == answer {
                        spec.value(v)
                    } else {
                        let d = distract[di];
                        di += 1;
                        spec.value(d)
                    };
                }
                Probe {
                    prompt: vec![GrammarSpec::BOS, spec.entity(e), spec.relation(r)],
                    choices,
                    answer,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GrammarSpec {
        GrammarSpec::default_for_vocab(512)
    }

    #[test]
    fn token_classes_disjoint_and_in_vocab() {
        let s = spec();
        let e: Vec<i32> = (0..s.n_entities).map(|i| s.entity(i)).collect();
        let r: Vec<i32> = (0..s.n_relations).map(|i| s.relation(i)).collect();
        let v: Vec<i32> = (0..s.n_values).map(|i| s.value(i)).collect();
        assert!(e.iter().all(|t| !r.contains(t) && !v.contains(t)));
        assert!(r.iter().all(|t| !v.contains(t)));
        assert!((s.first_filler() as i32) > *v.iter().max().unwrap());
        assert!(s.first_filler() < s.vocab);
    }

    #[test]
    fn corpus_deterministic_and_in_range() {
        let a = Corpus::generate(spec(), 10_000, 1000, 7);
        let b = Corpus::generate(spec(), 10_000, 1000, 7);
        assert_eq!(a.train, b.train);
        assert_eq!(a.train.len(), 10_000);
        assert_eq!(a.eval.len(), 1000);
        assert!(a.train.iter().all(|&t| t >= 0 && (t as usize) < 512));
    }

    #[test]
    fn facts_are_deterministic_function() {
        let s = spec();
        for e in 0..s.n_entities {
            for r in 0..s.n_relations {
                assert_eq!(s.fact(e, r), s.fact(e, r));
                assert!(s.fact(e, r) < s.n_values);
            }
        }
    }

    #[test]
    fn fact_structure_present_in_stream() {
        // every entity token is followed by a relation token then the
        // correct value token
        let s = spec();
        let c = Corpus::generate(s, 50_000, 100, 9);
        let is_entity = |t: i32| (2..2 + s.n_entities as i32).contains(&t);
        let mut checked = 0;
        for w in c.train.windows(3) {
            if is_entity(w[0]) {
                let e = (w[0] - 2) as usize;
                let rel_base = 2 + s.n_entities as i32;
                if w[1] >= rel_base && w[1] < rel_base + s.n_relations as i32 {
                    let r = (w[1] - rel_base) as usize;
                    assert_eq!(w[2], s.value(s.fact(e, r)));
                    checked += 1;
                }
            }
        }
        assert!(checked > 1000, "only {checked} facts found");
    }

    #[test]
    fn batches_have_right_shape() {
        let c = Corpus::generate(spec(), 10_000, 1000, 7);
        let mut rng = Rng::seeded(1);
        let b = c.batch(&c.train, 4, 32, &mut rng);
        assert_eq!(b.len(), 4 * 33);
    }

    #[test]
    fn probes_have_unique_choices_and_correct_answer() {
        let s = spec();
        for p in Probe::generate(&s, 200, 3) {
            let mut uniq = p.choices.to_vec();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 4, "duplicate choices {:?}", p.choices);
            // answer is consistent with the grammar
            let e = (p.prompt[1] - 2) as usize;
            let rel_base = 2 + s.n_entities;
            let r = (p.prompt[2] as usize) - rel_base;
            assert_eq!(p.choices[p.answer], s.value(s.fact(e, r)));
        }
    }

    #[test]
    fn answer_position_balanced() {
        let s = spec();
        let probes = Probe::generate(&s, 1000, 5);
        let mut counts = [0usize; 4];
        for p in &probes {
            counts[p.answer] += 1;
        }
        for c in counts {
            assert!(c > 150, "answer positions skewed: {counts:?}");
        }
    }
}
