//! Transformer shape specs.
//!
//! * [`LmSpec`] — the in-repo LM trained/evaluated through the AOT JAX
//!   artifacts. **The parameter flattening order defined here is a contract
//!   with `python/compile/model.py`** (`param_specs` must match the Python
//!   `param_names()` exactly); both sides are checked by tests.
//! * [`NamedModel`] — published-LLM shape tables used for the bit-true
//!   footprint axes of Fig. 9 (weights + KV cache at a given sequence
//!   length), where absolute GB numbers matter.

use crate::formats::NxConfig;

/// Shape of the in-repo language model (must mirror python/compile/model.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LmSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl LmSpec {
    /// The default trained model (~3.4M params — small enough to train for
    /// a few hundred CPU steps, big enough to show format-ordering effects).
    pub fn small() -> Self {
        LmSpec { vocab: 512, d_model: 256, n_layers: 4, n_heads: 4, d_ff: 1024, seq_len: 128 }
    }

    /// A tiny spec for fast integration tests.
    pub fn tiny() -> Self {
        LmSpec { vocab: 64, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 64, seq_len: 16 }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameter tensors in flattening order: `(name, rows, cols)`.
    /// 1-D tensors (norm gains) are `(1, d)`.
    pub fn param_specs(&self) -> Vec<(String, usize, usize)> {
        let d = self.d_model;
        let mut out = vec![
            ("embed".to_string(), self.vocab, d),
            ("pos_embed".to_string(), self.seq_len, d),
        ];
        for l in 0..self.n_layers {
            out.push((format!("l{l}.ln1"), 1, d));
            out.push((format!("l{l}.wq"), d, d));
            out.push((format!("l{l}.wk"), d, d));
            out.push((format!("l{l}.wv"), d, d));
            out.push((format!("l{l}.wo"), d, d));
            out.push((format!("l{l}.ln2"), 1, d));
            out.push((format!("l{l}.w1"), d, self.d_ff));
            out.push((format!("l{l}.w2"), self.d_ff, d));
        }
        out.push(("lnf".to_string(), 1, d));
        out.push(("unembed".to_string(), d, self.vocab));
        out
    }

    pub fn param_count(&self) -> usize {
        self.param_specs().iter().map(|(_, r, c)| r * c).sum()
    }

    /// Names of the matmul weights that get quantized in the weight-only
    /// experiments (norm gains and embeddings stay FP16, as in the paper's
    /// "quantize the weights that dominate footprint" setting).
    pub fn quantizable(&self) -> Vec<String> {
        self.param_specs()
            .into_iter()
            .filter(|(n, r, _)| *r > 1 && n != "embed" && n != "pos_embed")
            .map(|(n, _, _)| n)
            .collect()
    }
}

/// Published-model shape profile (decoder-only, GQA-aware) for footprint
/// accounting.
#[derive(Clone, Copy, Debug)]
pub struct NamedModel {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
}

impl NamedModel {
    #[rustfmt::skip]
    pub fn all() -> Vec<NamedModel> {
        vec![
            NamedModel { name: "Llama3-8B",   vocab: 128_256, d_model: 4096, n_layers: 32, n_heads: 32, n_kv_heads: 8,  d_ff: 14336 },
            NamedModel { name: "Llama3.1-8B", vocab: 128_256, d_model: 4096, n_layers: 32, n_heads: 32, n_kv_heads: 8,  d_ff: 14336 },
            NamedModel { name: "Phi3-4B",     vocab: 32_064,  d_model: 3072, n_layers: 32, n_heads: 32, n_kv_heads: 32, d_ff: 8192 },
            NamedModel { name: "Llama2-7B",   vocab: 32_000,  d_model: 4096, n_layers: 32, n_heads: 32, n_kv_heads: 32, d_ff: 11008 },
            NamedModel { name: "Llama2-13B",  vocab: 32_000,  d_model: 5120, n_layers: 40, n_heads: 40, n_kv_heads: 40, d_ff: 13824 },
            NamedModel { name: "Mistral-7B",  vocab: 32_000,  d_model: 4096, n_layers: 32, n_heads: 32, n_kv_heads: 8,  d_ff: 14336 },
        ]
    }

    pub fn by_name(name: &str) -> Option<NamedModel> {
        Self::all().into_iter().find(|m| m.name == name)
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Quantizable weight-element count (attention + MLP, SwiGLU: 3 MLP
    /// mats; embeddings/norms excluded, matching the paper's weight-only
    /// setting).
    pub fn weight_elements(&self) -> u64 {
        let d = self.d_model as u64;
        let kv = (self.n_kv_heads * self.head_dim()) as u64;
        let per_layer = d * d        // wq
            + d * kv                 // wk
            + d * kv                 // wv
            + d * d                  // wo
            + 3 * d * self.d_ff as u64; // SwiGLU gate/up/down
        per_layer * self.n_layers as u64
    }

    /// Embedding + unembedding elements (kept FP16).
    pub fn embed_elements(&self) -> u64 {
        2 * (self.vocab as u64) * self.d_model as u64
    }

    /// KV-cache element count at a sequence length (per batch=1).
    pub fn kv_elements(&self, seq_len: usize) -> u64 {
        2 * (self.n_layers as u64)
            * (self.n_kv_heads as u64)
            * (self.head_dim() as u64)
            * seq_len as u64
    }

    /// Total footprint in GB with weights (and optionally KV) quantized
    /// under `cfg`; embeddings stay FP16. `None` cfg means FP16 everywhere.
    pub fn footprint_gb(
        &self,
        cfg: Option<&NxConfig>,
        kv_cfg: Option<&NxConfig>,
        seq_len: usize,
    ) -> f64 {
        let w_bits = match cfg {
            Some(c) => c.footprint_bits(self.weight_elements() as usize) as f64,
            None => self.weight_elements() as f64 * 16.0,
        };
        let kv = self.kv_elements(seq_len);
        let kv_bits = match kv_cfg {
            Some(c) => c.footprint_bits(kv as usize) as f64,
            None => kv as f64 * 16.0,
        };
        let embed_bits = self.embed_elements() as f64 * 16.0;
        (w_bits + kv_bits + embed_bits) / 8.0 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_spec_param_count_about_3m() {
        let n = LmSpec::small().param_count();
        assert!(n > 3_000_000 && n < 4_000_000, "n={n}");
    }

    #[test]
    fn param_specs_order_is_stable() {
        let specs = LmSpec::tiny().param_specs();
        assert_eq!(specs[0].0, "embed");
        assert_eq!(specs[1].0, "pos_embed");
        assert_eq!(specs[2].0, "l0.ln1");
        assert_eq!(specs.last().unwrap().0, "unembed");
        // 2 + 8 per layer + 2
        assert_eq!(specs.len(), 2 + 8 * 2 + 2);
    }

    #[test]
    fn quantizable_excludes_embeddings_and_norms() {
        let q = LmSpec::tiny().quantizable();
        assert!(q.contains(&"l0.wq".to_string()));
        assert!(q.contains(&"unembed".to_string()));
        assert!(!q.iter().any(|n| n.contains("ln")));
        assert!(!q.contains(&"embed".to_string()));
    }

    #[test]
    fn llama3_8b_weight_count_plausible() {
        // ~8B params total; attention+MLP ≈ 6.98e9
        let m = NamedModel::by_name("Llama3-8B").unwrap();
        let w = m.weight_elements() as f64;
        assert!(w > 6.0e9 && w < 7.5e9, "w={w}");
    }

    #[test]
    fn fp16_footprint_matches_public_numbers() {
        // Llama3-8B FP16 ≈ 16 GB of weights (+1GB embeds here); paper Fig. 9
        // x-axis starts ~16GB at 2K sequence.
        let m = NamedModel::by_name("Llama3-8B").unwrap();
        let gb = m.footprint_gb(None, None, 2048);
        assert!(gb > 14.0 && gb < 18.0, "gb={gb}");
    }

    #[test]
    fn nxfp5_vs_mxfp6_footprint_reduction_matches_paper() {
        // paper §7.4: NxFP5 saves ~0.93GB (13%) of quantized-weight footprint
        // vs MxFP6 on Llama3-8B
        let m = NamedModel::by_name("Llama3-8B").unwrap();
        let nx5 = NxConfig::nxfp(5);
        let mx6 = NxConfig::mxfp(6);
        let a = nx5.footprint_bits(m.weight_elements() as usize) as f64 / 8e9;
        let b = mx6.footprint_bits(m.weight_elements() as usize) as f64 / 8e9;
        let saving = b - a;
        assert!(saving > 0.7 && saving < 1.1, "saving={saving}GB");
    }

    #[test]
    fn gqa_kv_cache_smaller_than_mha() {
        let llama3 = NamedModel::by_name("Llama3-8B").unwrap(); // GQA 8 kv heads
        let llama2 = NamedModel::by_name("Llama2-7B").unwrap(); // MHA
        assert!(llama3.kv_elements(2048) < llama2.kv_elements(2048));
    }
}
