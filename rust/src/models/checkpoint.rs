//! Binary checkpoints: named f32 tensors saved/loaded with the in-tree
//! serializer. Used to persist the trained LM between the training example
//! and the evaluation benches.

use crate::formats::packed::PackedMatrix;
use crate::formats::{NxConfig, PlanTable, QuantPolicy, TensorClass};
use crate::models::transformer::LmSpec;
use crate::tensor::Tensor2;
use crate::util::ser::{Reader, Writer};
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::{BufReader, BufWriter};
use std::path::Path;

/// An ordered set of named parameter tensors.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub params: Vec<(String, Tensor2)>,
    /// training metadata: steps completed and final train loss
    pub steps: u32,
    pub final_loss: f32,
}

impl Checkpoint {
    /// Initialize parameters for a spec (matches the Python initializer:
    /// scaled-normal matmuls, ones for norm gains). Used for shape tests;
    /// real training initializes on the JAX side.
    pub fn init(spec: &LmSpec, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::seeded(seed);
        let params = spec
            .param_specs()
            .into_iter()
            .map(|(name, r, c)| {
                let t = if r == 1 {
                    Tensor2::from_vec(1, c, vec![1.0; c])
                } else {
                    let std = 0.02f32.min((2.0 / (r + c) as f32).sqrt());
                    Tensor2::random_normal(r, c, std, &mut rng)
                };
                (name, t)
            })
            .collect();
        Checkpoint { params, steps: 0, final_loss: f32::NAN }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor2> {
        self.params.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor2> {
        self.params.iter_mut().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|(_, t)| t.len()).sum()
    }

    /// Validate the checkpoint against a spec's parameter contract.
    pub fn check_spec(&self, spec: &LmSpec) -> Result<()> {
        let want = spec.param_specs();
        if want.len() != self.params.len() {
            bail!("param count mismatch: {} vs {}", self.params.len(), want.len());
        }
        for ((wn, wr, wc), (n, t)) in want.iter().zip(&self.params) {
            if wn != n || *wr != t.rows || *wc != t.cols {
                bail!("param {n} has shape {}x{}, want {wn} {wr}x{wc}", t.rows, t.cols);
            }
        }
        Ok(())
    }

    /// Direct-cast the named tensors straight into deployable packed form
    /// (paper §5 Algorithm 1 → §6 storage layout) under a [`QuantPolicy`]:
    /// each weight is quantized through its **resolved** config by the
    /// allocation-free engine into a flat `BlockStore` and bit-packed
    /// without ever materializing per-block heap objects. One
    /// `EncodePlan` is built per distinct resolved config (a shared
    /// [`PlanTable`]), never per tensor. FP16-resolved names are omitted
    /// from the result (they stay unquantized); names missing from the
    /// checkpoint are skipped. Each entry carries the config that packed
    /// it, which a mixed policy makes tensor-dependent.
    pub fn direct_cast_packed(
        &self,
        names: &[String],
        policy: &QuantPolicy,
    ) -> Vec<(String, NxConfig, PackedMatrix)> {
        let mut plans = PlanTable::new(policy);
        self.params
            .iter()
            .filter(|(n, _)| names.contains(n))
            .filter_map(|(n, t)| {
                let (cfg, plan) = plans.resolve(TensorClass::weight(n))?;
                let packed = crate::quant::quantize_matrix_with(t, cfg, plan).pack(cfg);
                Some((n.clone(), cfg.clone(), packed))
            })
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let f = File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = Writer::new(BufWriter::new(f))?;
        w.u32(self.steps)?;
        w.f32(self.final_loss)?;
        w.u32(self.params.len() as u32)?;
        for (name, t) in &self.params {
            w.str(name)?;
            w.u64(t.rows as u64)?;
            w.u64(t.cols as u64)?;
            w.f32_slice(&t.data)?;
        }
        w.finish()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let f = File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = Reader::new(BufReader::new(f))?;
        let steps = r.u32()?;
        let final_loss = r.f32()?;
        let n = r.u32()? as usize;
        let mut params = Vec::with_capacity(n);
        for _ in 0..n {
            let name = r.str()?;
            let rows = r.u64()? as usize;
            let cols = r.u64()? as usize;
            let data = r.f32_slice()?;
            if data.len() != rows * cols {
                bail!("tensor {name}: data len {} != {rows}x{cols}", data.len());
            }
            params.push((name, Tensor2::from_vec(rows, cols, data)));
        }
        Ok(Checkpoint { params, steps, final_loss })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_matches_spec_contract() {
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 1);
        ck.check_spec(&spec).unwrap();
        assert_eq!(ck.param_count(), spec.param_count());
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join("nxfp_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.ckpt");
        let spec = LmSpec::tiny();
        let mut ck = Checkpoint::init(&spec, 2);
        ck.steps = 17;
        ck.final_loss = 3.25;
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.steps, 17);
        assert_eq!(back.final_loss, 3.25);
        assert_eq!(back.params.len(), ck.params.len());
        for ((n1, t1), (n2, t2)) in ck.params.iter().zip(&back.params) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn check_spec_catches_mismatch() {
        let ck = Checkpoint::init(&LmSpec::tiny(), 1);
        assert!(ck.check_spec(&LmSpec::small()).is_err());
    }

    #[test]
    fn direct_cast_packed_round_trips_and_shrinks() {
        use crate::formats::NxConfig;
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 5);
        let names = spec.quantizable();
        let cfg = NxConfig::nxfp(4);
        let packed = ck.direct_cast_packed(&names, &QuantPolicy::uniform(cfg.clone()));
        assert_eq!(packed.len(), names.len());
        let lut = crate::dequant::DequantLut::new(&cfg);
        for (name, pcfg, p) in &packed {
            assert_eq!(pcfg, &cfg);
            let t = ck.get(name).unwrap();
            assert_eq!((p.rows, p.cols), (t.rows, t.cols));
            // packed form is the same number system as the fake-quant path
            let back = crate::dequant::dequantize_packed(p, &lut, true);
            let want = crate::quant::quantize_matrix(t, &cfg).dequantize(&cfg);
            assert_eq!(back.data, want.data, "{name}");
            // and much smaller than fp16
            assert!(p.footprint_bytes() < t.len() * 2);
        }
    }

    #[test]
    fn direct_cast_packed_honors_mixed_policy() {
        use crate::formats::NxConfig;
        let spec = LmSpec::tiny();
        let ck = Checkpoint::init(&spec, 6);
        let names = spec.quantizable();
        // layer 0 at 6 bits, layer 1 stays fp16, the rest at 4 bits
        let policy =
            QuantPolicy::parse("layers.0.weights=mxfp6,layers.1.weights=fp16,weights=nxfp4")
                .unwrap();
        let packed = ck.direct_cast_packed(&names, &policy);
        // layer-1 weights are fp16-resolved and omitted
        assert!(packed.iter().all(|(n, ..)| !n.starts_with("l1.")));
        assert_eq!(packed.len(), names.len() - 6);
        for (name, cfg, p) in &packed {
            let want_bits = if name.starts_with("l0.") { 6 } else { 4 };
            assert_eq!(cfg.bits, want_bits, "{name}");
            assert_eq!(p.bits, want_bits, "{name}");
        }
    }

    #[test]
    fn norm_gains_init_to_one() {
        let ck = Checkpoint::init(&LmSpec::tiny(), 1);
        let ln = ck.get("l0.ln1").unwrap();
        assert!(ln.data.iter().all(|&x| x == 1.0));
    }
}
