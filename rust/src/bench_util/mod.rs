//! In-tree benchmark harness (criterion is unavailable offline).
//!
//! Every `cargo bench` target uses [`bench`] for timing (warmup + fixed
//! measurement budget, mean/p50/p99 over iterations) and [`Table`] for
//! printing the paper-style result grids.

pub mod scenario;

use std::time::{Duration, Instant};

/// Timing summary over iterations.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl Timing {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }

    /// Throughput given bytes processed per iteration.
    pub fn gib_per_sec(&self, bytes_per_iter: usize) -> f64 {
        bytes_per_iter as f64 / self.mean.as_secs_f64() / (1u64 << 30) as f64
    }
}

impl std::fmt::Display for Timing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:>10.3?}  p50 {:>10.3?}  p99 {:>10.3?}  ({} iters)",
            self.mean, self.p50, self.p99, self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` seconds of warmup then measure for roughly
/// `measure` seconds (at least 5 iterations). Use `std::hint::black_box` in
/// the closure to keep work alive.
pub fn bench<F: FnMut()>(warmup: Duration, measure: Duration, mut f: F) -> Timing {
    let wstart = Instant::now();
    let mut warm_iters = 0u64;
    while wstart.elapsed() < warmup || warm_iters < 1 {
        f();
        warm_iters += 1;
    }
    let mut samples: Vec<Duration> = Vec::new();
    let mstart = Instant::now();
    while mstart.elapsed() < measure || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    Timing {
        iters: n as u64,
        mean: total / n as u32,
        p50: samples[n / 2],
        p99: samples[(n * 99 / 100).min(n - 1)],
        min: samples[0],
    }
}

/// Quick bench with default budgets (0.3s warmup / 1s measure).
pub fn bench_quick<F: FnMut()>(f: F) -> Timing {
    bench(Duration::from_millis(300), Duration::from_secs(1), f)
}

/// Time each of `n` sequential calls `f(i)` and return the per-call
/// durations **in call order**. For stateful workloads whose per-iteration
/// cost may drift (e.g. a KV cache growing across decode steps), where the
/// sorted aggregate of [`bench`] would hide the trend.
pub fn bench_series<F: FnMut(usize)>(n: usize, mut f: F) -> Vec<Duration> {
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t = Instant::now();
        f(i);
        out.push(t.elapsed());
    }
    out
}

/// Mean of a duration slice (empty slices -> zero).
pub fn mean_duration(samples: &[Duration]) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    samples.iter().sum::<Duration>() / samples.len() as u32
}

/// True when `NXFP_BENCH_SMOKE` requests a seconds-scale smoke run (the
/// CI hot-path steps set this; any non-empty value other than "0" counts).
pub fn smoke_env() -> bool {
    std::env::var("NXFP_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Version of the bench-JSON record layout. Bumped when the meaning of a
/// shared field changes; additive fields do not bump it. `bench_compare.py`
/// accepts records with or without the version stamp (pre-versioning
/// baselines) and skips the meta fields when diffing numerics.
pub const BENCH_SCHEMA_VERSION: u64 = 1;

/// Monotonic per-process sequence of emitted bench records, so a reader
/// can reconstruct emission order even after lines from several benches
/// are concatenated or sorted.
static RUN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Append one machine-readable result record so the perf trajectory is
/// tracked across PRs. When `NXFP_BENCH_JSON=<dir>` is set, the record is
/// appended as one JSON line to `<dir>/BENCH_<bench>.json` (the directory
/// is created if needed); without the env var this is a no-op. `policy`
/// is the quantization-policy name of the run (`QuantPolicy::name()`, or
/// `"fp16"`/`"fp32"` for unquantized baselines) so the trajectory can
/// distinguish mixed-precision runs that share a `config` label.
/// `fields` are numeric measurements (tok/s, p95 ms, speedups,
/// effective_bits); non-finite values serialize as `null`. Every record
/// carries `schema_version` ([`BENCH_SCHEMA_VERSION`]) and a per-process
/// `run_seq` emission counter.
///
/// ```json
/// {"bench":"scheduler","name":"continuous","config":"NxFP4 (NM+AM+CR)",
///  "policy":"NxFP4 (NM+AM+CR)","smoke":false,"schema_version":1,
///  "run_seq":0,"tok_s":1234.5,"p95_ms":8.1,"effective_bits":4.34}
/// ```
pub fn emit_bench_json(
    bench: &str,
    name: &str,
    config: &str,
    policy: &str,
    fields: &[(&str, f64)],
) {
    let Ok(dir) = std::env::var("NXFP_BENCH_JSON") else { return };
    if dir.is_empty() {
        return;
    }
    let esc = |s: &str| -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    };
    let seq = RUN_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut line = format!(
        "{{\"bench\":\"{}\",\"name\":\"{}\",\"config\":\"{}\",\"policy\":\"{}\",\"smoke\":{},\
         \"schema_version\":{BENCH_SCHEMA_VERSION},\"run_seq\":{seq}",
        esc(bench),
        esc(name),
        esc(config),
        esc(policy),
        smoke_env()
    );
    for (k, v) in fields {
        if v.is_finite() {
            line.push_str(&format!(",\"{}\":{v}", esc(k)));
        } else {
            line.push_str(&format!(",\"{}\":null", esc(k)));
        }
    }
    line.push_str("}\n");
    let path = std::path::Path::new(&dir).join(format!("BENCH_{bench}.json"));
    let write = || -> std::io::Result<()> {
        std::fs::create_dir_all(&dir)?;
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
        f.write_all(line.as_bytes())
    };
    if let Err(e) = write() {
        eprintln!("[bench] could not append {path:?}: {e}");
    }
}

/// Deterministic TTFT-in-**steps** tracker for scheduler benches and
/// tests: after every engine step, call [`StepTtft::observe`] with the
/// live slots and [`StepTtft::observe_done`] with the step's completed
/// responses (a request can produce its first token on the very step it
/// finishes, when its slot is already retired). The first engine step at
/// which each request had generated a token is recorded. Wall-clock TTFT
/// lives in `ServingMetrics::ttft`; this counter is the
/// machine-independent version the chunked-prefill assertions compare.
#[derive(Default)]
pub struct StepTtft {
    first: std::collections::BTreeMap<u64, u64>,
}

impl StepTtft {
    pub fn new() -> Self {
        StepTtft::default()
    }

    /// Record any live slot that has produced its first token by `step`.
    pub fn observe(&mut self, step: u64, slots: &[Option<crate::coordinator::Slot>]) {
        for sl in slots.iter().flatten() {
            if sl.generated() > 0 {
                self.first.entry(sl.request_id()).or_insert(step);
            }
        }
    }

    /// Record requests that completed at `step` (covers first tokens
    /// produced on a slot's final step).
    pub fn observe_done(&mut self, step: u64, done: &[crate::coordinator::GenResponse]) {
        for r in done {
            if r.generated > 0 {
                self.first.entry(r.id).or_insert(step);
            }
        }
    }

    /// First-token step for one request, if it has produced a token.
    pub fn get(&self, id: u64) -> Option<u64> {
        self.first.get(&id).copied()
    }

    pub fn count(&self) -> usize {
        self.first.len()
    }

    pub fn mean(&self) -> f64 {
        if self.first.is_empty() {
            return 0.0;
        }
        self.first.values().sum::<u64>() as f64 / self.first.len() as f64
    }

    /// p-quantile over the recorded first-token steps (p in 0..=1).
    pub fn quantile(&self, p: f64) -> u64 {
        if self.first.is_empty() {
            return 0;
        }
        let mut s: Vec<u64> = self.first.values().copied().collect();
        s.sort_unstable();
        s[nearest_rank(s.len(), p)]
    }
}

/// Nearest-rank index of the p-quantile in a sorted series of `n > 0`
/// elements (`ceil(p·n)` as a 0-based index, clamped into range) — the
/// one order-statistic rule every quantile helper here shares.
fn nearest_rank(n: usize, p: f64) -> usize {
    ((p.clamp(0.0, 1.0) * n as f64).ceil() as usize).saturating_sub(1).min(n - 1)
}

/// p-quantile of a duration series (sorted copy; p in 0..=1).
pub fn quantile_duration(samples: &[Duration], p: f64) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    let mut s = samples.to_vec();
    s.sort();
    s[nearest_rank(s.len(), p)]
}

/// First-quarter mean, last-quarter mean, and their ratio ("growth") of a
/// per-step duration series — the flatness metric the hot-path benches
/// report: ≈1 means per-step cost does not grow with accumulated state.
pub fn quartile_growth(series: &[Duration]) -> (Duration, Duration, f64) {
    if series.is_empty() {
        return (Duration::ZERO, Duration::ZERO, 1.0);
    }
    let q = (series.len() / 4).max(1);
    let first = mean_duration(&series[..q]);
    let last = mean_duration(&series[series.len() - q..]);
    let growth = last.as_secs_f64() / first.as_secs_f64().max(1e-12);
    (first, last, growth)
}

/// Fixed-width table printer for paper-style result grids.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Standard bench banner so all bench outputs are grep-able.
pub fn banner(id: &str, what: &str) {
    println!("\n=== {id} — {what} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_timing() {
        let t = bench(Duration::from_millis(1), Duration::from_millis(20), || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.iters >= 5);
        assert!(t.min <= t.p50 && t.p50 <= t.p99);
        assert!(t.mean.as_nanos() > 0);
    }

    #[test]
    fn bench_series_preserves_order() {
        let mut seen = Vec::new();
        let s = bench_series(4, |i| seen.push(i));
        assert_eq!(s.len(), 4);
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert!(mean_duration(&s) <= s.iter().sum());
        assert_eq!(mean_duration(&[]), Duration::ZERO);
    }

    #[test]
    fn quartile_growth_flat_and_growing() {
        let flat = vec![Duration::from_micros(10); 8];
        let (f, l, g) = quartile_growth(&flat);
        assert_eq!(f, l);
        assert!((g - 1.0).abs() < 1e-9);
        let growing: Vec<Duration> = (1..=8).map(Duration::from_micros).collect();
        let (f, l, g) = quartile_growth(&growing);
        assert!(l > f && g > 1.0);
        // tiny series degrade gracefully
        let (_, _, g) = quartile_growth(&[Duration::from_micros(5)]);
        assert!((g - 1.0).abs() < 1e-9);
    }

    #[test]
    fn step_ttft_records_first_token_step_once() {
        use crate::coordinator::{FinishReason, GenResponse};
        let mut t = StepTtft::new();
        assert_eq!(t.mean(), 0.0);
        assert_eq!(t.quantile(0.5), 0);
        let resp = |id: u64, generated: usize| GenResponse {
            id,
            tokens: vec![0; generated],
            generated,
            latency: Duration::ZERO,
            reason: FinishReason::Completed,
        };
        t.observe_done(3, &[resp(0, 2)]);
        t.observe_done(5, &[resp(0, 4), resp(1, 1), resp(2, 0)]);
        assert_eq!(t.get(0), Some(3)); // first sighting wins
        assert_eq!(t.get(1), Some(5));
        assert_eq!(t.get(2), None); // zero generated: no first token
        assert_eq!(t.count(), 2);
        assert_eq!(t.mean(), 4.0);
        assert_eq!(t.quantile(0.5), 3);
        assert_eq!(t.quantile(1.0), 5);
    }

    #[test]
    fn quantile_duration_picks_order_stats() {
        let s: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        assert_eq!(quantile_duration(&s, 0.5), Duration::from_micros(50));
        assert_eq!(quantile_duration(&s, 0.95), Duration::from_micros(95));
        assert_eq!(quantile_duration(&s, 1.0), Duration::from_micros(100));
        assert_eq!(quantile_duration(&[], 0.5), Duration::ZERO);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["fmt", "ppl"]);
        t.row(&["MxFP4".into(), "6.95".into()]);
        t.row(&["NxFP4 (NM+AM+CR)".into(), "6.57".into()]);
        let s = t.render();
        assert!(s.contains("NxFP4"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    fn throughput_math() {
        let t = Timing {
            iters: 1,
            mean: Duration::from_secs(1),
            p50: Duration::from_secs(1),
            p99: Duration::from_secs(1),
            min: Duration::from_secs(1),
        };
        assert!((t.gib_per_sec(1 << 30) - 1.0).abs() < 1e-12);
    }
}
