//! Shared scenario setup for the paper-figure benches: the canonical
//! corpus, probes, and the trained checkpoint (trained on demand if the
//! end-to-end example has not been run yet).

use anyhow::Result;
use std::path::Path;

use crate::models::{Checkpoint, Corpus, GrammarSpec, LmSpec};
use crate::runtime::Runtime;
use crate::train::{TrainConfig, Trainer};

/// The canonical corpus every experiment evaluates against (Wikitext2
/// stand-in; see DESIGN.md §3). The bench eval split is kept small enough
/// for the single-core PJRT CPU of this testbed (env `NXFP_EVAL_TOKENS`
/// overrides; the e2e example uses the full 40k split).
pub fn default_corpus() -> Corpus {
    let eval_tokens = std::env::var("NXFP_EVAL_TOKENS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(14_000);
    Corpus::generate(GrammarSpec::default_for_vocab(512), 400_000, eval_tokens, 1234)
}

/// Load `artifacts/model.ckpt`, or train one (fewer steps than the e2e
/// example, still enough to separate the formats) and cache it there.
pub fn load_or_train(rt: &mut Runtime, corpus: &Corpus, seed: u64) -> Result<Checkpoint> {
    let spec = LmSpec::small();
    let suffix = if seed == 42 { String::new() } else { format!("_s{seed}") };
    let path = format!("artifacts/model{suffix}.ckpt");
    let path = Path::new(&path);
    if path.exists() {
        let ck = Checkpoint::load(path)?;
        ck.check_spec(&spec)?;
        return Ok(ck);
    }
    let steps: u32 = std::env::var("NXFP_BENCH_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    eprintln!("[bench setup] {path:?} missing — training {steps} steps (seed {seed})…");
    let cfg = TrainConfig { batch: 16, steps, log_every: 40, seed };
    let init = Checkpoint::init(&spec, seed);
    let mut tr = Trainer::new(rt, spec, &init, &cfg)?;
    tr.train(corpus, &cfg, |s, l| eprintln!("[bench setup] step {s} loss {l:.3}"))?;
    let ck = tr.checkpoint()?;
    ck.save(path)?;
    Ok(ck)
}
