//! Quantized KV-cache manager (paper §7.4 "quantizing the weights and KV
//! cache", §6 deployment). Keys/values are stored in packed NxFP/MxFP/BFP
//! form — the DRAM-resident footprint — and dequantized on the fly when a
//! decode step needs the attention context.
//!
//! # Per-stream formats and plan interning
//!
//! Since the `QuantPolicy` redesign the K and V streams carry **their own
//! configs**: a cache is built from two [`KvStreamPlan`]s (config +
//! `EncodePlan` + `DequantLut` behind `Arc`s), so `kv.k=nxfp5,kv.v=mxfp4`
//! is just two different plans, and [`KvPlans::from_policy`] resolves a
//! whole engine's per-layer, per-stream plan table with **one**
//! plan/LUT pair per distinct config — admission of a serving slot clones
//! `Arc`s instead of rebuilding `n_layers` encode plans (the pre-policy
//! behavior). The packed streams already carry per-block metadata, so
//! mixed formats are purely a plumbing concern; the stored bits per
//! stream are identical to a uniform cache of that stream's config
//! (pinned by `tests/policy_equivalence.rs`).
//!
//! # Paged storage and copy-on-write prefix sharing
//!
//! Storage is **paged**: each stream holds a page table of [`PageId`]s
//! into a shared refcounted [`PagePool`], every page a fixed-row-count
//! [`BlockStore`] fragment laid out exactly like the old flat stream
//! (pages concatenate bit-identically — [`KvCache::stores`] materializes
//! the flat view on demand for tests). Row `r` lives in page
//! `r / page_rows` at local row `r % page_rows`. This is what lets two
//! serving slots whose prompts share a token prefix *share the packed
//! pages covering it*: [`KvCache::adopt_pages`] maps a donor's prefix
//! pages in read-only (refcount bump, zero copies), and the first
//! divergent append copy-on-writes only the partially-covered tail page
//! ([`PagePool::cow`]). Full shared pages are never copied.
//!
//! The encode hot path is unchanged: [`KvCache::append`] quantizes
//! through the stream's resident [`EncodePlan`] + a shared
//! [`EncodeScratch`] straight into the exclusively-owned tail page —
//! zero heap allocations per appended row in steady state apart from the
//! amortized page-granular grows.
//!
//! # Incremental dequantization contract
//!
//! Serving appends one row per decode step, so re-decoding the whole cache
//! every step makes per-request decode work O(S²). [`KvCache`] therefore
//! keeps a **dirty-row watermark**: [`KvCache::dequantize_into`] decodes
//! only the rows appended since the previous call into caller-owned
//! staging tensors and advances the watermark. The contract is:
//!
//! * the caller passes the *same* destination buffer (or a bit-identical
//!   copy, e.g. after a lane-to-lane slab move) across calls and does not
//!   overwrite previously decoded rows;
//! * rows `0..watermark()` in the destination are then always
//!   bit-identical to what a fresh [`KvCache::dequantize`] would produce
//!   (both paths share one decode routine), and padding rows stay zero;
//! * if the destination's contents are lost or were never populated — the
//!   slot was reassigned to a fresh lane, or the cache adopted packed
//!   prefix pages that have never been decoded into this lane — the
//!   watermark is (or is reset to) 0 and the next
//!   [`KvCache::dequantize_into_slab`] decodes every row from packed;
//! * [`KvCache::clear`] resets both the cache and the watermark (the
//!   caller must also zero or discard its staging buffers).
//!
//! The watermark is a **logical row counter** — paging does not change
//! it. An adopted prefix starts with watermark 0, so its first decode
//! materializes the whole shared prefix from packed pages into the lane
//! (that one decode pass is the entire prefill cost of a prefix hit).
//!
//! Since PR 3 the decode destination is a raw `&mut [f32]` slab — the
//! serving coordinator points it directly at the slot's lane of the batched
//! step tensors, so there is no intermediate staging mirror (see
//! `coordinator::SlotKv`).

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::dequant::DequantLut;
use crate::formats::{
    BaseFormat, BlockStore, EncodePlan, EncodeScratch, KvStream as StreamKind, NxConfig,
    QuantPolicy, TensorClass,
};
use crate::obs::CodeOccupancy;
use crate::quant::page::{PageId, PagePool, DEFAULT_KV_PAGE_ROWS};
use crate::tensor::Tensor2;

/// Interned runtime tables for one stream's config: the config itself,
/// its encode plan, and its decode LUT, all shareable across layers,
/// slots, and threads. Build once per **distinct** config (see
/// [`KvPlans::from_policy`]); cloning is three `Arc` bumps.
#[derive(Clone)]
pub struct KvStreamPlan {
    pub cfg: Arc<NxConfig>,
    pub plan: Arc<EncodePlan>,
    pub lut: Arc<DequantLut>,
}

impl KvStreamPlan {
    pub fn new(cfg: &NxConfig) -> Self {
        let plan = EncodePlan::new(cfg);
        let lut = DequantLut::from_tables(cfg.bits, &plan.tabs);
        KvStreamPlan {
            cfg: Arc::new(cfg.clone()),
            plan: Arc::new(plan),
            lut: Arc::new(lut),
        }
    }
}

/// A whole engine's resolved KV formats: one `(K, V)` plan pair per layer,
/// with plans interned per distinct config. This is what a `QuantPolicy`
/// lowers to on the serving side.
#[derive(Clone)]
pub struct KvPlans {
    /// `layers[l] = (key_plan, value_plan)`.
    pub layers: Vec<(KvStreamPlan, KvStreamPlan)>,
}

impl KvPlans {
    /// Resolve `policy` for every `(layer, stream)` KV class.
    ///
    /// * all classes FP16 → `Ok(None)` (baseline serving, no quantizer);
    /// * all classes quantized → one [`KvStreamPlan`] per **distinct**
    ///   config, shared across every layer/stream that resolves to it;
    /// * a mix of FP16 and quantized streams → error: the serving slabs
    ///   hold either raw rows or packed caches per slot, not both (state
    ///   the whole cache as quantized, or none of it).
    pub fn from_policy(policy: &QuantPolicy, n_layers: usize) -> Result<Option<KvPlans>> {
        let mut interned: Vec<Option<KvStreamPlan>> = vec![None; policy.configs().len()];
        let intern = |id: usize, interned: &mut Vec<Option<KvStreamPlan>>| {
            if interned[id].is_none() {
                interned[id] = Some(KvStreamPlan::new(policy.config(id)));
            }
            interned[id].clone().unwrap()
        };
        let mut ids = Vec::with_capacity(n_layers);
        let mut any_q = false;
        let mut any_fp = false;
        for l in 0..n_layers {
            let k = policy.resolve_id(TensorClass::kv(l, StreamKind::Key));
            let v = policy.resolve_id(TensorClass::kv(l, StreamKind::Value));
            for id in [k, v] {
                match id {
                    Some(_) => any_q = true,
                    None => any_fp = true,
                }
            }
            ids.push((k, v));
        }
        if !any_q {
            return Ok(None);
        }
        if any_fp {
            bail!(
                "policy `{}` mixes FP16 and quantized KV streams; per-layer/per-stream \
                 formats may differ but must all be quantized (or all FP16)",
                policy.render()
            );
        }
        let layers = ids
            .into_iter()
            .map(|(k, v)| {
                (intern(k.unwrap(), &mut interned), intern(v.unwrap(), &mut interned))
            })
            .collect();
        Ok(Some(KvPlans { layers }))
    }

    /// One config for every layer and both streams (a single shared plan).
    pub fn uniform(cfg: &NxConfig, n_layers: usize) -> KvPlans {
        let p = KvStreamPlan::new(cfg);
        KvPlans { layers: vec![(p.clone(), p); n_layers] }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }
}

/// One packed stream (K or V): its plan plus a page table into the shared
/// pool. `rows` is the stream's logical length; pages `0..n-1` are always
/// full (`page_rows` rows each) and the tail page holds `rows % page_rows`
/// rows once this stream has appended into it — an *adopted* tail may
/// transiently hold extra donor rows until the first divergent append
/// truncates or copy-on-writes them away.
struct Stream {
    plan: KvStreamPlan,
    pool: Rc<RefCell<PagePool>>,
    pages: Vec<PageId>,
    rows: usize,
    row_len: usize,
    blocks_per_row: usize,
    /// Optional live code-occupancy probe fed from the encode hot path.
    /// `None` (the default) costs one branch per appended row.
    probe: Option<Rc<RefCell<CodeOccupancy>>>,
}

impl Stream {
    fn new(dim: usize, plan: KvStreamPlan, rows: usize, pool: Rc<RefCell<PagePool>>) -> Self {
        let blocks_per_row = dim.div_ceil(plan.cfg.block_size);
        let table_cap = rows.div_ceil(pool.borrow().page_rows().max(1));
        Stream {
            plan,
            pool,
            pages: Vec::with_capacity(table_cap),
            rows: 0,
            row_len: dim,
            blocks_per_row,
            probe: None,
        }
    }

    /// Make the tail page exclusively writable with exactly
    /// `rows % page_rows` local rows, allocating / copy-on-writing /
    /// truncating as needed, and return its id. The write gate of the
    /// COW contract: shared tails are split here and nowhere else.
    fn writable_tail(&mut self) -> PageId {
        let mut pool = self.pool.borrow_mut();
        let local = self.rows % pool.page_rows();
        if local == 0 {
            // Page boundary: every prior page is exactly full (adopted
            // page-aligned prefixes only ever donate full pages), so the
            // next row starts a fresh exclusively-owned page.
            let id = pool.alloc(self.row_len, self.plan.cfg.block_size);
            self.pages.push(id);
            return id;
        }
        let id = *self.pages.last().unwrap();
        if pool.refs(id) > 1 {
            // Shared tail (prefix adoption): diverge onto a private copy
            // of just the rows we cover. Sharers keep the original.
            let new_id = pool.cow(id, local);
            *self.pages.last_mut().unwrap() = new_id;
            return new_id;
        }
        if pool.rows(id) > local {
            // Exclusively ours, but it still carries donor rows beyond
            // our coverage (the sharer side evicted first): drop them.
            pool.store_mut(id).truncate_rows(local);
        }
        id
    }

    /// Quantize-append one row through this stream's plan.
    fn append_row(&mut self, row: &[f32], scratch: &mut EncodeScratch) {
        let id = self.writable_tail();
        let mut pool = self.pool.borrow_mut();
        let store = pool.store_mut(id);
        let r = store.push_row();
        let (codes, e, nano, fmt) = store.row_slices_mut(r);
        self.plan.plan.quantize_row_into(row, scratch, codes, e, nano, fmt);
        if let Some(p) = &self.probe {
            p.borrow_mut().observe_row(&self.plan.plan, row, codes, e, nano, fmt);
        }
        self.rows += 1;
    }

    /// Bulk-append `n` rows. Storage grows page-granular (at most
    /// `ceil(n / page_rows) + 1` grows per chunk instead of one per
    /// token); per-row encoding is unchanged, so the packed bits are
    /// bit-identical to `n` single appends by construction.
    fn append_rows(&mut self, rows: &[f32], dim: usize, scratch: &mut EncodeScratch) {
        for row in rows.chunks(dim) {
            self.append_row(row, scratch);
        }
    }

    /// Adopt `rows` logical rows held by the given prefix pages (refcount
    /// bump per page, zero copies). Only valid on an empty stream.
    fn adopt(&mut self, rows: usize, ids: &[PageId]) {
        assert_eq!(self.rows, 0, "adopt into a non-empty stream");
        assert!(self.pages.is_empty());
        let mut pool = self.pool.borrow_mut();
        assert_eq!(ids.len(), rows.div_ceil(pool.page_rows()), "page table mismatch");
        for &id in ids {
            pool.retain(id);
            self.pages.push(id);
        }
        self.rows = rows;
    }

    /// Truncate to `rows` logical rows (speculative-decode rollback),
    /// releasing every wholly-trailing page back to the pool. The tail
    /// page is handled like an adopted tail: if it is exclusively owned
    /// its extra rows are dropped eagerly (cheap [`BlockStore`]
    /// truncation); if it is shared, the extra rows stay — exactly the
    /// slack the struct invariant allows — and the next append truncates
    /// or copy-on-writes them away via [`Stream::writable_tail`].
    /// [`Stream::dequant_rows`] and [`Stream::materialize`] never read
    /// past `self.rows`, so readers are oblivious either way.
    fn truncate(&mut self, rows: usize) {
        assert!(rows <= self.rows, "truncate cannot grow a stream");
        if rows == self.rows {
            return;
        }
        let mut pool = self.pool.borrow_mut();
        let page_rows = pool.page_rows();
        let keep = rows.div_ceil(page_rows);
        for id in self.pages.drain(keep..) {
            pool.release(id);
        }
        self.rows = rows;
        if let Some(&tail) = self.pages.last() {
            let local = rows - (keep - 1) * page_rows;
            if pool.refs(tail) == 1 && pool.rows(tail) > local {
                pool.store_mut(tail).truncate_rows(local);
            }
        }
    }

    /// Shared decode routine: rows `from..to` into the row-major `out`
    /// slab (`dim` floats per row). Both the full and the incremental
    /// path go through here, which is what makes them bit-identical by
    /// construction.
    fn dequant_rows(&self, dim: usize, out: &mut [f32], from: usize, to: usize) {
        let cfg = &*self.plan.cfg;
        let lut = &*self.plan.lut;
        let base_mx = cfg.base == BaseFormat::Mx;
        let pool = self.pool.borrow();
        let page_rows = pool.page_rows();
        for r in from..to {
            let store = pool.store(self.pages[r / page_rows]);
            let local = r % page_rows;
            let row = &mut out[r * dim..(r + 1) * dim];
            for (bi, chunk) in row.chunks_mut(cfg.block_size).enumerate() {
                let flat = local * self.blocks_per_row + bi;
                let fmt_mx = if cfg.enable_am {
                    store.fmt_mx[flat] != 0
                } else {
                    base_mx
                };
                let (table, offset) = lut.table(fmt_mx);
                let scale = (1.0 + store.nano[flat] as f32 / 4.0)
                    * crate::util::exp2i(store.e_shared[flat] as i32 + offset);
                for (o, &c) in chunk.iter_mut().zip(store.block_codes(flat)) {
                    *o = table[c as usize] * scale;
                }
            }
        }
    }

    /// Concatenate the page prefixes into one flat [`BlockStore`] —
    /// bit-identical to the pre-paging layout (pages never straddle rows,
    /// so rows concatenate freely; an adopted tail's extra donor rows are
    /// clipped to this stream's logical length).
    fn materialize(&self, dim: usize) -> BlockStore {
        let pool = self.pool.borrow();
        let page_rows = pool.page_rows();
        let mut out = BlockStore::new(dim, self.plan.cfg.block_size);
        out.reserve_rows(self.rows);
        let mut remaining = self.rows;
        for &id in &self.pages {
            let take = remaining.min(page_rows);
            out.append_rows_from(pool.store(id), take);
            remaining -= take;
        }
        debug_assert_eq!(remaining, 0);
        out
    }

    /// Dedup-aware footprint charge: bits of every not-yet-accounted page
    /// this stream references, marking them accounted. Shared pages are
    /// thereby charged exactly once pool-wide.
    fn take_dedup_bits(&self, dim: usize) -> u64 {
        let mut pool = self.pool.borrow_mut();
        let bits_per_row = self.plan.cfg.footprint_bits(dim);
        let mut total = 0u64;
        for &id in &self.pages {
            if pool.mark_accounted(id) {
                total += pool.rows(id) as u64 * bits_per_row;
            }
        }
        total
    }

    /// Release every page reference (pool recycles zero-ref pages).
    fn clear(&mut self) {
        let mut pool = self.pool.borrow_mut();
        for id in self.pages.drain(..) {
            pool.release(id);
        }
        self.rows = 0;
    }
}

impl Drop for Stream {
    fn drop(&mut self) {
        self.clear();
    }
}

/// One layer's quantized K and V streams. Rows are appended per generated
/// token; each row is quantized independently in that stream's
/// `block_size` blocks along the feature dimension (matching how the
/// paper blocks the cache). The two streams may carry different configs.
pub struct KvCache {
    k: Stream,
    v: Stream,
    scratch: EncodeScratch,
    pub dim: usize,
    pub len: usize,
    /// Rows already materialized by the last [`KvCache::dequantize_into`].
    clean: usize,
}

impl KvCache {
    /// Uniform convenience: both streams under one config.
    pub fn new(dim: usize, cfg: NxConfig) -> Self {
        Self::with_capacity(dim, cfg, 0)
    }

    /// Like [`KvCache::new`], but sizes the page tables for `rows`
    /// appended rows up front (pages themselves allocate on demand).
    pub fn with_capacity(dim: usize, cfg: NxConfig, rows: usize) -> Self {
        let plan = KvStreamPlan::new(&cfg);
        Self::with_plans(dim, plan.clone(), plan, rows)
    }

    /// Per-stream plans with a **private** page pool (default page
    /// geometry) — the standalone-cache path used by tests and non-serving
    /// callers. Serving slots share one engine-wide pool via
    /// [`KvCache::with_plans_in`].
    pub fn with_plans(dim: usize, k: KvStreamPlan, v: KvStreamPlan, rows: usize) -> Self {
        let pool = Rc::new(RefCell::new(PagePool::new(DEFAULT_KV_PAGE_ROWS)));
        Self::with_plans_in(dim, k, v, rows, pool)
    }

    /// Per-stream plans over a caller-provided shared [`PagePool`] — the
    /// serving path: every slot's caches borrow pages from the engine's
    /// pool, which is what makes cross-slot prefix sharing possible.
    pub fn with_plans_in(
        dim: usize,
        k: KvStreamPlan,
        v: KvStreamPlan,
        rows: usize,
        pool: Rc<RefCell<PagePool>>,
    ) -> Self {
        KvCache {
            k: Stream::new(dim, k, rows, pool.clone()),
            v: Stream::new(dim, v, rows, pool),
            scratch: EncodeScratch::new(),
            dim,
            len: 0,
            clean: 0,
        }
    }

    /// The pool this cache's pages live in (both streams share it).
    pub fn page_pool(&self) -> Rc<RefCell<PagePool>> {
        self.k.pool.clone()
    }

    /// Attach live [`CodeOccupancy`] probes to the K and V streams. Every
    /// subsequently appended row is observed (adopted prefix rows are
    /// not — they were observed when the donor encoded them). Tables are
    /// shared `Rc`s so many slots can feed one per-config aggregate.
    pub fn set_probes(
        &mut self,
        k: Option<Rc<RefCell<CodeOccupancy>>>,
        v: Option<Rc<RefCell<CodeOccupancy>>>,
    ) {
        self.k.probe = k;
        self.v.probe = v;
    }

    /// The key stream's config.
    pub fn cfg_k(&self) -> &NxConfig {
        &self.k.plan.cfg
    }

    /// The value stream's config.
    pub fn cfg_v(&self) -> &NxConfig {
        &self.v.plan.cfg
    }

    /// Quantize and append one (k, v) row pair.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        self.k.append_row(k, &mut self.scratch);
        self.v.append_row(v, &mut self.scratch);
        self.len += 1;
    }

    /// Quantize and append `n` (k, v) row pairs in one bulk operation
    /// (the chunked-prefill path). Storage grows page-granular instead of
    /// once per token; every row is encoded through the same
    /// `quantize_row_into` routine as [`KvCache::append`] — the packed
    /// bits are identical to `n` single-row appends by construction.
    /// `k_rows`/`v_rows` are row-major `[n, dim]`.
    pub fn append_rows(&mut self, k_rows: &[f32], v_rows: &[f32], n: usize) {
        assert_eq!(k_rows.len(), n * self.dim);
        assert_eq!(v_rows.len(), n * self.dim);
        if n == 0 {
            return;
        }
        self.k.append_rows(k_rows, self.dim, &mut self.scratch);
        self.v.append_rows(v_rows, self.dim, &mut self.scratch);
        self.len += n;
    }

    /// Adopt a shared prompt prefix: map `rows` logical rows held by the
    /// given (K-pages, V-pages) tables into this **empty** cache, bumping
    /// each page's refcount — zero rows are copied or re-quantized. The
    /// watermark stays 0, so the next decode materializes the adopted
    /// prefix from packed into the slot's lane; the first append past the
    /// prefix copy-on-writes a partially-covered tail page.
    pub fn adopt_pages(&mut self, rows: usize, k_ids: &[PageId], v_ids: &[PageId]) {
        assert_eq!(self.len, 0, "adopt into a non-empty cache");
        self.k.adopt(rows, k_ids);
        self.v.adopt(rows, v_ids);
        self.len = rows;
    }

    /// The (K, V) page tables — what a prefix-cache registration records.
    pub fn page_ids(&self) -> (&[PageId], &[PageId]) {
        (&self.k.pages, &self.v.pages)
    }

    /// Pages currently referenced per stream `(K, V)`.
    pub fn page_count(&self) -> (usize, usize) {
        (self.k.pages.len(), self.v.pages.len())
    }

    /// Dedup-aware footprint charge `(K bits, V bits)`: bits of every
    /// referenced page not yet charged pool-wide, marking them charged.
    /// Summed over all slots, shared pages count **once** — with prefix
    /// sharing off this equals [`KvCache::footprint_bits_split`] summed
    /// over slots, since every page then has exactly one owner.
    pub fn take_dedup_bits(&self) -> (u64, u64) {
        (self.k.take_dedup_bits(self.dim), self.v.take_dedup_bits(self.dim))
    }

    /// Rows already decoded into the caller's staging tensors (the
    /// dirty-row watermark). Rows `watermark()..len` are pending.
    pub fn watermark(&self) -> usize {
        self.clean
    }

    /// The packed (K, V) streams materialized as flat [`BlockStore`]s —
    /// bit-identical to the pre-paging layout regardless of page geometry.
    /// Exposed so the chunk-invariance and policy-equivalence tests can
    /// pin bit-identity of the packed streams; hot paths never need this
    /// (it allocates and copies — the stored bits live in the pages).
    pub fn stores(&self) -> (BlockStore, BlockStore) {
        (self.k.materialize(self.dim), self.v.materialize(self.dim))
    }

    /// Dequantize the whole cache into `(len, dim)` tensors, padded to
    /// `pad_len` rows of zeros (decode-step artifacts take fixed shapes).
    pub fn dequantize(&self, pad_len: usize) -> (Tensor2, Tensor2) {
        assert!(pad_len >= self.len);
        let mut k = Tensor2::zeros(pad_len, self.dim);
        let mut v = Tensor2::zeros(pad_len, self.dim);
        self.k.dequant_rows(self.dim, &mut k.data, 0, self.len);
        self.v.dequant_rows(self.dim, &mut v.data, 0, self.len);
        (k, v)
    }

    /// Incrementally decode rows appended since the previous call straight
    /// into the caller's row-major `[rows >= len, dim]` slabs (a batch-lane
    /// layer region, padding pre-zeroed), advance the watermark, and return
    /// the decoded row range. See the module docs for the full contract.
    pub fn dequantize_into_slab(&mut self, k: &mut [f32], v: &mut [f32]) -> std::ops::Range<usize> {
        let need = self.len * self.dim;
        assert!(k.len() >= need && v.len() >= need, "slab too short");
        let (from, to) = (self.clean, self.len);
        self.k.dequant_rows(self.dim, k, from, to);
        self.v.dequant_rows(self.dim, v, from, to);
        self.clean = to;
        from..to
    }

    /// Tensor-shaped convenience wrapper over
    /// [`KvCache::dequantize_into_slab`] (tests and non-lane callers).
    pub fn dequantize_into(&mut self, k: &mut Tensor2, v: &mut Tensor2) -> std::ops::Range<usize> {
        assert!(k.rows >= self.len && v.rows >= self.len, "staging too short");
        assert_eq!(k.cols, self.dim);
        assert_eq!(v.cols, self.dim);
        self.dequantize_into_slab(&mut k.data, &mut v.data)
    }

    /// Forget decode progress: the next [`KvCache::dequantize_into_slab`]
    /// re-decodes every stored row from the packed pages. The
    /// lane-reassignment fallback — when a slot moves to a lane whose
    /// previous contents are unknown and a lane-to-lane slab copy was not
    /// possible, the packed pages are the only source of truth left.
    /// (This is also exactly the state [`KvCache::adopt_pages`] leaves a
    /// fresh cache in: packed rows, watermark 0.)
    pub fn reset_watermark(&mut self) {
        self.clean = 0;
    }

    /// Roll the cache back to its first `rows` rows (the
    /// speculative-decode rejection path): wholly-trailing pages are
    /// released per stream, both logical lengths shrink, and the dirty-row
    /// watermark clamps — lane rows `0..rows` were decoded bit-exactly and
    /// are never re-synced, while the next
    /// [`KvCache::dequantize_into_slab`] resumes from the truncation
    /// point. The caller owns zeroing any stale lane rows beyond `rows`
    /// (the same division of labor `move_lane` has with its vacated lane).
    pub fn truncate_rows(&mut self, rows: usize) {
        assert!(rows <= self.len, "truncate_rows cannot grow a cache");
        if rows == self.len {
            return;
        }
        self.k.truncate(rows);
        self.v.truncate(rows);
        self.len = rows;
        self.clean = self.clean.min(rows);
    }

    /// Bit-true stored footprint of the cache (both K and V).
    pub fn footprint_bits(&self) -> u64 {
        let (k, v) = self.footprint_bits_split();
        k + v
    }

    /// Per-stream bit-true footprint `(K bits, V bits)` — distinct under a
    /// mixed policy, and what the serving metrics' per-class breakdown
    /// aggregates.
    pub fn footprint_bits_split(&self) -> (u64, u64) {
        let rows = self.len as u64;
        (
            rows * self.k.plan.cfg.footprint_bits(self.dim),
            rows * self.v.plan.cfg.footprint_bits(self.dim),
        )
    }

    /// FP16 footprint of the same cache, for the savings headline.
    pub fn fp16_footprint_bits(&self) -> u64 {
        2 * (self.len * self.dim) as u64 * 16
    }

    /// Release every page reference and reset the cache to empty (pages
    /// whose refcount hits zero are recycled by the pool).
    pub fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.len = 0;
        self.clean = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats::mse;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_dequantize_round_trip() {
        let mut rng = Rng::seeded(71);
        let dim = 64;
        let mut cache = KvCache::new(dim, NxConfig::nxfp(5));
        let mut rows = Vec::new();
        for _ in 0..10 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(&k, &v);
            rows.push((k, v));
        }
        let (kd, vd) = cache.dequantize(16);
        for (r, (k, v)) in rows.iter().enumerate() {
            assert!(mse(kd.row(r), k) < 0.01, "row {r} K mse too big");
            assert!(mse(vd.row(r), v) < 0.01);
        }
        // padding rows are zero
        for r in 10..16 {
            assert!(kd.row(r).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn append_matches_reference_quantizer() {
        // the cache's engine path must store the exact blocks the
        // reference `formats::quantize_block` produces
        let mut rng = Rng::seeded(74);
        let dim = 45; // partial tail block
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(6), NxConfig::nxfp(5)] {
            let tabs = cfg.tables();
            let bpr = dim.div_ceil(cfg.block_size);
            let mut cache = KvCache::new(dim, cfg.clone());
            let mut appended = Vec::new();
            for _ in 0..4 {
                let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                cache.append(&k, &k);
                appended.push(k);
            }
            let (ks, vs) = cache.stores();
            for (r, k) in appended.iter().enumerate() {
                for (bi, chunk) in k.chunks(cfg.block_size).enumerate() {
                    let want = crate::formats::quantize_block(chunk, &cfg, &tabs);
                    let flat = r * bpr + bi;
                    assert_eq!(ks.block(flat), want, "{}", cfg.name());
                    assert_eq!(vs.block(flat), want, "{}", cfg.name());
                }
            }
        }
    }

    #[test]
    fn mixed_stream_formats_match_uniform_caches() {
        // a kv.k=nxfp5 / kv.v=mxfp4 cache must store per stream the exact
        // bits two uniform caches of those configs store (the policy
        // redesign is plumbing, not a format change)
        let mut rng = Rng::seeded(79);
        let dim = 45;
        let (ck, cv) = (NxConfig::nxfp(5), NxConfig::mxfp(4));
        let mut mixed = KvCache::with_plans(dim, KvStreamPlan::new(&ck), KvStreamPlan::new(&cv), 8);
        let mut uk = KvCache::new(dim, ck.clone());
        let mut uv = KvCache::new(dim, cv.clone());
        for _ in 0..6 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            mixed.append(&k, &v);
            uk.append(&k, &k);
            uv.append(&v, &v);
        }
        assert_eq!(mixed.stores().0, uk.stores().0, "K stream diverged");
        assert_eq!(mixed.stores().1, uv.stores().1, "V stream diverged");
        // decoded rows agree with the uniform caches too
        let (mk, mv) = mixed.dequantize(6);
        assert_eq!(mk.data, uk.dequantize(6).0.data);
        assert_eq!(mv.data, uv.dequantize(6).1.data);
        // per-stream footprints follow their own configs
        let (kb, vb) = mixed.footprint_bits_split();
        assert_eq!(kb, 6 * ck.footprint_bits(dim));
        assert_eq!(vb, 6 * cv.footprint_bits(dim));
        assert_eq!(mixed.footprint_bits(), kb + vb);
        assert_eq!(mixed.cfg_k().name(), "NxFP5 (NM+AM+CR)");
        assert_eq!(mixed.cfg_v().name(), "MxFP4-E2M1");
    }

    #[test]
    fn kv_plans_from_policy_interns_and_validates() {
        // uniform policy: every plan is the same Arc
        let p = QuantPolicy::uniform(NxConfig::nxfp(4));
        let plans = KvPlans::from_policy(&p, 3).unwrap().unwrap();
        assert_eq!(plans.n_layers(), 3);
        let first = &plans.layers[0].0;
        for (k, v) in &plans.layers {
            assert!(Arc::ptr_eq(&first.plan, &k.plan));
            assert!(Arc::ptr_eq(&first.plan, &v.plan));
        }
        // fp16 policy: no plans at all
        assert!(KvPlans::from_policy(&QuantPolicy::fp16(), 3).unwrap().is_none());
        // weights-only policy leaves KV fp16
        let wo = QuantPolicy::parse("weights=nxfp4").unwrap();
        assert!(KvPlans::from_policy(&wo, 2).unwrap().is_none());
        // mixed streams intern two configs, shared across layers
        let m = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap();
        let plans = KvPlans::from_policy(&m, 4).unwrap().unwrap();
        assert_eq!(plans.layers[0].0.cfg.name(), "NxFP5 (NM+AM+CR)");
        assert_eq!(plans.layers[0].1.cfg.name(), "MxFP4-E2M1");
        for (k, v) in &plans.layers {
            assert!(Arc::ptr_eq(&plans.layers[0].0.plan, &k.plan));
            assert!(Arc::ptr_eq(&plans.layers[0].1.plan, &v.plan));
        }
        // partial fp16 is rejected with a policy-quoting error
        let bad = QuantPolicy::parse("kv.k=nxfp4").unwrap();
        let err = KvPlans::from_policy(&bad, 2).unwrap_err().to_string();
        assert!(err.contains("FP16"), "{err}");
        // per-layer resolution honors layer rules
        let l = QuantPolicy::parse("layers.0.kv=mxfp6,kv=nxfp4").unwrap();
        let plans = KvPlans::from_policy(&l, 2).unwrap().unwrap();
        assert_eq!(plans.layers[0].0.cfg.name(), "MxFP6-E2M3");
        assert_eq!(plans.layers[1].0.cfg.name(), "NxFP4 (NM+AM+CR)");
    }

    #[test]
    fn incremental_matches_full_dequantize() {
        let mut rng = Rng::seeded(73);
        let (dim, pad) = (48, 12);
        // odd dim -> partial tail block; cover all three format families
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(4)] {
            let mut cache = KvCache::new(dim, cfg);
            let mut k_stage = Tensor2::zeros(pad, dim);
            let mut v_stage = Tensor2::zeros(pad, dim);
            let mut decoded = 0usize;
            for chunk in [3usize, 1, 4, 2] {
                for _ in 0..chunk {
                    let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                    let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                    cache.append(&k, &v);
                }
                let range = cache.dequantize_into(&mut k_stage, &mut v_stage);
                assert_eq!(range, decoded..decoded + chunk);
                decoded += chunk;
                assert_eq!(cache.watermark(), decoded);
                // staging must be bit-identical to a fresh full decode
                let (k_full, v_full) = cache.dequantize(pad);
                assert_eq!(k_stage.data, k_full.data);
                assert_eq!(v_stage.data, v_full.data);
            }
            // no new rows -> empty range, buffers untouched
            let before = k_stage.data.clone();
            assert!(cache.dequantize_into(&mut k_stage, &mut v_stage).is_empty());
            assert_eq!(k_stage.data, before);
        }
    }

    #[test]
    fn reset_watermark_redecodes_everything() {
        // lane-reassignment fallback: after a reset, the next incremental
        // decode must rebuild the full prefix bit-identically from packed
        let mut rng = Rng::seeded(75);
        let (dim, pad) = (40, 8);
        let mut cache = KvCache::new(dim, NxConfig::nxfp(4));
        let mut k_lane = vec![0.0f32; pad * dim];
        let mut v_lane = vec![0.0f32; pad * dim];
        for _ in 0..6 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(&k, &v);
        }
        cache.dequantize_into_slab(&mut k_lane, &mut v_lane);
        assert_eq!(cache.watermark(), 6);
        // slot moved to a lane with unknown contents: reset + re-decode
        let mut new_k = vec![0.0f32; pad * dim];
        let mut new_v = vec![0.0f32; pad * dim];
        cache.reset_watermark();
        assert_eq!(cache.watermark(), 0);
        let range = cache.dequantize_into_slab(&mut new_k, &mut new_v);
        assert_eq!(range, 0..6);
        assert_eq!(new_k, k_lane);
        assert_eq!(new_v, v_lane);
    }

    #[test]
    fn page_geometry_tracks_appends() {
        // pages fill to exactly page_rows before a new one is allocated,
        // and the materialized flat view always covers len rows
        let dim = 40;
        let pool = Rc::new(RefCell::new(PagePool::new(4)));
        let plan = KvStreamPlan::new(&NxConfig::nxfp(4));
        let mut cache = KvCache::with_plans_in(dim, plan.clone(), plan, 0, pool.clone());
        let row: Vec<f32> = (0..dim).map(|i| i as f32 * 0.1).collect();
        for n in 1..=9 {
            cache.append(&row, &row);
            let want_pages = n.div_ceil(4);
            assert_eq!(cache.page_count(), (want_pages, want_pages), "len={n}");
            let (ks, _) = cache.stores();
            assert_eq!(ks.rows, n);
        }
        // K and V streams allocate separate pages from the shared pool
        assert_eq!(pool.borrow().live_pages(), 2 * 3);
        assert_eq!(pool.borrow().shared_pages(), 0);
        drop(cache);
        assert_eq!(pool.borrow().live_pages(), 0, "drop must release every page");
    }

    #[test]
    fn packed_bits_invariant_under_page_size() {
        // the flat materialized stream must not depend on page geometry:
        // any page_rows choice stores the exact same bits
        let mut rng = Rng::seeded(80);
        let dim = 45;
        let rows: Vec<f32> = (0..11 * dim).map(|_| rng.normal_f32(0.0, 1.2)).collect();
        let plan = KvStreamPlan::new(&NxConfig::nxfp(5));
        let reference = {
            let pool = Rc::new(RefCell::new(PagePool::new(1)));
            let mut c = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool);
            c.append_rows(&rows, &rows, 11);
            c.stores()
        };
        for page_rows in [2usize, 3, 4, 11, 64] {
            let pool = Rc::new(RefCell::new(PagePool::new(page_rows)));
            let mut c = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool);
            c.append_rows(&rows, &rows, 11);
            assert_eq!(c.stores(), reference, "page_rows={page_rows}");
            let (kd, vd) = c.dequantize(12);
            let mut k_lane = vec![0.0f32; 12 * dim];
            let mut v_lane = vec![0.0f32; 12 * dim];
            c.dequantize_into_slab(&mut k_lane, &mut v_lane);
            assert_eq!(&k_lane[..11 * dim], &kd.data[..11 * dim]);
            assert_eq!(&v_lane[..11 * dim], &vd.data[..11 * dim]);
        }
    }

    #[test]
    fn adopt_pages_shares_then_cow_diverges() {
        // two caches sharing a 6-row prefix over 4-row pages: page 0 is
        // shared whole, page 1 (2 of 4 rows adopted) copy-on-writes at the
        // first divergent append; the donor's bits never change
        let mut rng = Rng::seeded(81);
        let dim = 19;
        let cfg = NxConfig::nxfp(4).with_block_size(16); // page splits blocks mid-row
        let plan = KvStreamPlan::new(&cfg);
        let pool = Rc::new(RefCell::new(PagePool::new(4)));
        let mk_row = |rng: &mut Rng| -> Vec<f32> {
            (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect()
        };
        let prefix: Vec<Vec<f32>> = (0..6).map(|_| mk_row(&mut rng)).collect();
        let mut donor = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        for r in &prefix {
            donor.append(r, r);
        }
        let donor_stores = donor.stores();
        let (k_ids, v_ids) = {
            let (k, v) = donor.page_ids();
            (k.to_vec(), v.to_vec())
        };
        let mut adopter = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        adopter.adopt_pages(6, &k_ids, &v_ids);
        assert_eq!(adopter.len, 6);
        assert_eq!(adopter.watermark(), 0);
        assert_eq!(pool.borrow().shared_pages(), 4); // 2 pages x 2 streams
        // adopted view is bit-identical to the donor's prefix
        assert_eq!(adopter.stores(), donor_stores);
        // divergence: adopter appends its own rows; donor appends others
        let div_a = mk_row(&mut rng);
        let div_d = mk_row(&mut rng);
        adopter.append(&div_a, &div_a);
        donor.append(&div_d, &div_d);
        assert_eq!(pool.borrow().shared_pages(), 2); // only the full pages remain shared
        assert!(pool.borrow().cow_copies() >= 2); // adopter's K and V tails split
        // both caches now match from-scratch controls built row by row
        let mut ctl_a = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        let mut ctl_d = KvCache::with_plans_in(dim, plan.clone(), plan, 0, pool.clone());
        for r in &prefix {
            ctl_a.append(r, r);
            ctl_d.append(r, r);
        }
        ctl_a.append(&div_a, &div_a);
        ctl_d.append(&div_d, &div_d);
        assert_eq!(adopter.stores(), ctl_a.stores(), "adopter diverged from control");
        assert_eq!(donor.stores(), ctl_d.stores(), "donor corrupted by COW");
        // lifecycle: dropping everything empties the pool
        drop((donor, adopter, ctl_a, ctl_d));
        assert_eq!(pool.borrow().live_pages(), 0);
    }

    #[test]
    fn dedup_bits_charge_shared_pages_once() {
        let dim = 32;
        let cfg = NxConfig::nxfp(4);
        let plan = KvStreamPlan::new(&cfg);
        let pool = Rc::new(RefCell::new(PagePool::new(4)));
        let row = vec![0.5f32; dim];
        let mut donor = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        for _ in 0..8 {
            donor.append(&row, &row);
        }
        let (k_ids, v_ids) = {
            let (k, v) = donor.page_ids();
            (k.to_vec(), v.to_vec())
        };
        let mut adopter = KvCache::with_plans_in(dim, plan.clone(), plan, 0, pool.clone());
        adopter.adopt_pages(8, &k_ids, &v_ids);
        let bits_per_row = cfg.footprint_bits(dim);
        // the per-slot packed view double-counts the shared rows
        assert_eq!(donor.footprint_bits(), 2 * 8 * bits_per_row);
        assert_eq!(adopter.footprint_bits(), 2 * 8 * bits_per_row);
        // the dedup charge hands the bits to the first caller only
        let (dk, dv) = donor.take_dedup_bits();
        assert_eq!((dk, dv), (8 * bits_per_row, 8 * bits_per_row));
        assert_eq!(adopter.take_dedup_bits(), (0, 0));
        // repeated charge stays zero
        assert_eq!(donor.take_dedup_bits(), (0, 0));
    }

    #[test]
    fn append_rows_bit_identical_to_single_appends() {
        // bulk chunk encoding must store the exact bytes the per-token
        // path stores, incl. partial tail blocks (dim 45, block 32 ->
        // 13-element tails) and chunk splits at every offset
        let mut rng = Rng::seeded(76);
        let dim = 45;
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(4)] {
            let n = 7;
            let k_rows: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
            let v_rows: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
            let mut single = KvCache::new(dim, cfg.clone());
            for r in 0..n {
                single.append(&k_rows[r * dim..(r + 1) * dim], &v_rows[r * dim..(r + 1) * dim]);
            }
            for split in 0..=n {
                let mut bulk = KvCache::new(dim, cfg.clone());
                bulk.append_rows(&k_rows[..split * dim], &v_rows[..split * dim], split);
                bulk.append_rows(&k_rows[split * dim..], &v_rows[split * dim..], n - split);
                assert_eq!(bulk.len, n);
                assert_eq!(bulk.stores(), single.stores(), "{} split {split}", cfg.name());
            }
        }
    }

    #[test]
    fn watermark_on_empty_cache_is_a_noop() {
        let dim = 32;
        let mut cache = KvCache::new(dim, NxConfig::nxfp(4));
        let mut k = vec![7.0f32; 4 * dim];
        let mut v = vec![7.0f32; 4 * dim];
        // empty cache: decode range is empty and the slab is untouched
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..0);
        assert!(k.iter().all(|&x| x == 7.0));
        cache.reset_watermark();
        assert_eq!(cache.watermark(), 0);
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..0);
        // a zero-length slab is acceptable for a zero-length cache
        assert_eq!(cache.dequantize_into_slab(&mut [], &mut []), 0..0);
        assert_eq!(cache.footprint_bits(), 0);
    }

    #[test]
    fn watermark_at_exact_window_fill() {
        // fill a cache to exactly its context window through a mix of
        // bulk and single appends: the watermark decode into an
        // exactly-sized slab stays correct across page boundaries
        let mut rng = Rng::seeded(77);
        let (dim, rows) = (40, 12); // partial tail block (block 32)
        let mut cache = KvCache::with_capacity(dim, NxConfig::nxfp(4), rows);
        let mut k_lane = vec![0.0f32; rows * dim]; // exactly-window slab
        let mut v_lane = vec![0.0f32; rows * dim];
        let chunk: Vec<f32> = (0..5 * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        cache.append_rows(&chunk, &chunk, 5);
        cache.dequantize_into_slab(&mut k_lane, &mut v_lane);
        let row: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for _ in 0..3 {
            cache.append(&row, &row);
        }
        let tail: Vec<f32> = (0..4 * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        cache.append_rows(&tail, &tail, 4);
        assert_eq!(cache.len, rows);
        assert_eq!(cache.dequantize_into_slab(&mut k_lane, &mut v_lane), 5..rows);
        assert_eq!(cache.watermark(), rows);
        // bit-identical to a from-scratch full decode
        let (k_full, v_full) = cache.dequantize(rows);
        assert_eq!(k_lane, k_full.data);
        assert_eq!(v_lane, v_full.data);
        // 12 rows over the default 16-row pages: one page per stream
        assert_eq!(cache.page_count(), (1, 1));
    }

    #[test]
    fn partial_tail_blocks_after_bulk_append() {
        // dim 19 with block 16: every row ends in a 3-element tail block
        // split mid-row by the block boundary; bulk appends must decode
        // bit-identically to the reference per-row dequantize
        let mut rng = Rng::seeded(78);
        let dim = 19;
        let cfg = NxConfig::nxfp(4).with_block_size(16);
        let mut cache = KvCache::new(dim, cfg);
        let rows: Vec<f32> = (0..6 * dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        cache.append_rows(&rows, &rows, 6);
        let mut k = vec![0.0f32; 8 * dim];
        let mut v = vec![0.0f32; 8 * dim];
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..6);
        let (k_full, _) = cache.dequantize(8);
        assert_eq!(k, k_full.data);
        // tail blocks really are short
        let (ks, _) = cache.stores();
        assert_eq!(ks.blocks_per_row(), 2);
        assert_eq!(ks.block_codes(1).len(), 3);
    }

    #[test]
    fn footprint_savings_vs_fp16() {
        let mut cache = KvCache::new(128, NxConfig::nxfp(4));
        let row = vec![0.5f32; 128];
        for _ in 0..8 {
            cache.append(&row, &row);
        }
        let q = cache.footprint_bits() as f64;
        let fp16 = cache.fp16_footprint_bits() as f64;
        // 4.34 effective bits vs 16 -> ~3.7x smaller
        assert!(fp16 / q > 3.5, "ratio {}", fp16 / q);
    }

    #[test]
    fn matches_quant_module_semantics() {
        // cache dequant must agree with the reference fake_quant
        let mut rng = Rng::seeded(72);
        let dim = 96;
        let cfg = NxConfig::nxfp(4);
        let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut cache = KvCache::new(dim, cfg.clone());
        cache.append(&k, &k);
        let (kd, _) = cache.dequantize(1);
        let want = crate::quant::fake_quant(&k, &cfg);
        assert_eq!(kd.row(0), &want[..]);
    }

    #[test]
    fn clear_resets() {
        let mut cache = KvCache::new(32, NxConfig::mxfp(4));
        cache.append(&vec![1.0; 32], &vec![1.0; 32]);
        let mut k = Tensor2::zeros(4, 32);
        let mut v = Tensor2::zeros(4, 32);
        cache.dequantize_into(&mut k, &mut v);
        assert_eq!(cache.watermark(), 1);
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.watermark(), 0);
        assert_eq!(cache.footprint_bits(), 0);
    }

    #[test]
    fn truncate_rows_rolls_back_to_a_bitwise_prefix() {
        // the speculative-decode rollback primitive: cut an overshooting
        // cache back to a prefix and everything — packed stores, released
        // pages, watermark resume, appends after the cut — must match a
        // cache that never overshot
        let dim = 48usize;
        let mut rng = Rng::seeded(97);
        let pool = Rc::new(RefCell::new(PagePool::new(4)));
        let plan = KvStreamPlan::new(&NxConfig::nxfp(4));
        let mut cache = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        let mut control = KvCache::with_plans_in(dim, plan.clone(), plan, 0, pool.clone());
        let rows: Vec<Vec<f32>> = (0..11)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        for r in &rows {
            cache.append(r, r);
        }
        for r in &rows[..5] {
            control.append(r, r);
        }
        // decode everything so the watermark sits past the cut
        let mut k = vec![0.0f32; 16 * dim];
        let mut v = vec![0.0f32; 16 * dim];
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..11);
        let live_before = pool.borrow().live_pages();
        cache.truncate_rows(5);
        assert_eq!(cache.len, 5);
        assert_eq!(cache.watermark(), 5);
        // rows 8..11 lived on a wholly-trailing page per stream (page
        // geometry 4): exactly those two pages are released; the tail
        // page (rows 4..8) survives truncated in place
        assert_eq!(pool.borrow().live_pages(), live_before - 2);
        let (ck, cv) = cache.stores();
        let (wk, wv) = control.stores();
        assert_eq!(ck, wk);
        assert_eq!(cv, wv);
        // appends after the rollback continue bit-identically to a cache
        // that never overshot
        let fresh: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        cache.append(&fresh, &fresh);
        control.append(&fresh, &fresh);
        let (ck, cv) = cache.stores();
        let (wk, wv) = control.stores();
        assert_eq!(ck, wk);
        assert_eq!(cv, wv);
        // incremental decode resumes at the truncation point: only the
        // fresh row is re-synced and the decoded prefix matches a clean
        // control sync bit for bit (rows past the cut are the caller's
        // to zero — dequant never reads them)
        let mut k2 = vec![0.0f32; 16 * dim];
        let mut v2 = vec![0.0f32; 16 * dim];
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 5..6);
        control.dequantize_into_slab(&mut k2, &mut v2);
        assert_eq!(&k[..6 * dim], &k2[..6 * dim]);
        assert_eq!(&v[..6 * dim], &v2[..6 * dim]);
    }

    #[test]
    fn truncate_rows_leaves_shared_tails_for_cow() {
        // a rollback cutting into a *shared* (adopted) tail page must not
        // touch the stored rows — sharers keep reading them — and the
        // next divergent append copy-on-writes exactly like an adopted
        // prefix does
        let dim = 32usize;
        let mut rng = Rng::seeded(98);
        let pool = Rc::new(RefCell::new(PagePool::new(4)));
        let plan = KvStreamPlan::new(&NxConfig::nxfp(5));
        let mut donor = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        let rows: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        for r in &rows {
            donor.append(r, r);
        }
        let mut slot = KvCache::with_plans_in(dim, plan.clone(), plan.clone(), 0, pool.clone());
        {
            let (k_ids, v_ids) = donor.page_ids();
            let (k_ids, v_ids) = (k_ids.to_vec(), v_ids.to_vec());
            slot.adopt_pages(6, &k_ids, &v_ids);
        }
        slot.truncate_rows(5);
        assert_eq!(slot.len, 5);
        // the shared tail keeps both donor rows in storage (refcount > 1
        // forbids in-place truncation)…
        let tail = slot.page_ids().0[1];
        assert_eq!(pool.borrow().rows(tail), 2);
        // …but reads clip to the logical length
        let mut control = KvCache::with_plans_in(dim, plan.clone(), plan, 0, pool.clone());
        for r in &rows[..5] {
            control.append(r, r);
        }
        let (sk, sv) = slot.stores();
        let (wk, wv) = control.stores();
        assert_eq!(sk, wk);
        assert_eq!(sv, wv);
        // divergent append past the cut copy-on-writes the tail; the
        // donor's full 6 rows survive bit-exactly
        let div: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        slot.append(&div, &div);
        assert_ne!(slot.page_ids().0[1], donor.page_ids().0[1]);
        let mut donor_control =
            KvCache::with_plans_in(dim, KvStreamPlan::new(&NxConfig::nxfp(5)), KvStreamPlan::new(&NxConfig::nxfp(5)), 0, pool.clone());
        for r in &rows {
            donor_control.append(r, r);
        }
        let (dk, dv) = donor.stores();
        let (gk, gv) = donor_control.stores();
        assert_eq!(dk, gk);
        assert_eq!(dv, gv);
    }
}
