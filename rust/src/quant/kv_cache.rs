//! Quantized KV-cache manager (paper §7.4 "quantizing the weights and KV
//! cache", §6 deployment). Keys/values are stored in packed NxFP/MxFP/BFP
//! form — the DRAM-resident footprint — and dequantized on the fly when a
//! decode step needs the attention context.

use crate::dequant::DequantLut;
use crate::formats::{quantize_block, BaseFormat, BlockCode, FormatTables, NxConfig};
use crate::tensor::Tensor2;

/// One layer's quantized K and V streams. Rows are appended per generated
/// token; each row is quantized independently in `cfg.block_size` blocks
/// along the feature dimension (matching how the paper blocks the cache).
pub struct KvCache {
    pub cfg: NxConfig,
    tabs: FormatTables,
    lut: DequantLut,
    pub dim: usize,
    k_blocks: Vec<BlockCode>,
    v_blocks: Vec<BlockCode>,
    pub len: usize,
    blocks_per_row: usize,
}

impl KvCache {
    pub fn new(dim: usize, cfg: NxConfig) -> Self {
        let tabs = cfg.tables();
        let lut = DequantLut::from_tables(cfg.bits, &tabs);
        let blocks_per_row = dim.div_ceil(cfg.block_size);
        KvCache { cfg, tabs, lut, dim, k_blocks: Vec::new(), v_blocks: Vec::new(), len: 0, blocks_per_row }
    }

    /// Quantize and append one (k, v) row pair.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        for chunk in k.chunks(self.cfg.block_size) {
            self.k_blocks.push(quantize_block(chunk, &self.cfg, &self.tabs));
        }
        for chunk in v.chunks(self.cfg.block_size) {
            self.v_blocks.push(quantize_block(chunk, &self.cfg, &self.tabs));
        }
        self.len += 1;
    }

    fn dequant_stream(&self, blocks: &[BlockCode], out: &mut Tensor2) {
        let base_mx = self.cfg.base == BaseFormat::Mx;
        for r in 0..self.len {
            let row = out.row_mut(r);
            for (bi, chunk) in row.chunks_mut(self.cfg.block_size).enumerate() {
                let b = &blocks[r * self.blocks_per_row + bi];
                let fmt_mx = if self.cfg.enable_am { b.fmt_mx } else { base_mx };
                let (table, offset) = self.lut.table(fmt_mx);
                let scale = (1.0 + b.nano as f32 / 4.0)
                    * crate::util::exp2i(b.e_shared as i32 + offset);
                for (o, &c) in chunk.iter_mut().zip(&b.codes) {
                    *o = table[c as usize] * scale;
                }
            }
        }
    }

    /// Dequantize the whole cache into `(len, dim)` tensors, padded to
    /// `pad_len` rows of zeros (decode-step artifacts take fixed shapes).
    pub fn dequantize(&self, pad_len: usize) -> (Tensor2, Tensor2) {
        assert!(pad_len >= self.len);
        let mut k = Tensor2::zeros(pad_len, self.dim);
        let mut v = Tensor2::zeros(pad_len, self.dim);
        self.dequant_stream(&self.k_blocks, &mut k);
        self.dequant_stream(&self.v_blocks, &mut v);
        (k, v)
    }

    /// Bit-true stored footprint of the cache (both K and V).
    pub fn footprint_bits(&self) -> u64 {
        2 * self.len as u64 * self.cfg.footprint_bits(self.dim)
    }

    /// FP16 footprint of the same cache, for the savings headline.
    pub fn fp16_footprint_bits(&self) -> u64 {
        2 * (self.len * self.dim) as u64 * 16
    }

    pub fn clear(&mut self) {
        self.k_blocks.clear();
        self.v_blocks.clear();
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats::mse;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_dequantize_round_trip() {
        let mut rng = Rng::seeded(71);
        let dim = 64;
        let mut cache = KvCache::new(dim, NxConfig::nxfp(5));
        let mut rows = Vec::new();
        for _ in 0..10 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(&k, &v);
            rows.push((k, v));
        }
        let (kd, vd) = cache.dequantize(16);
        for (r, (k, v)) in rows.iter().enumerate() {
            assert!(mse(kd.row(r), k) < 0.01, "row {r} K mse too big");
            assert!(mse(vd.row(r), v) < 0.01);
        }
        // padding rows are zero
        for r in 10..16 {
            assert!(kd.row(r).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn footprint_savings_vs_fp16() {
        let mut cache = KvCache::new(128, NxConfig::nxfp(4));
        let row = vec![0.5f32; 128];
        for _ in 0..8 {
            cache.append(&row, &row);
        }
        let q = cache.footprint_bits() as f64;
        let fp16 = cache.fp16_footprint_bits() as f64;
        // 4.34 effective bits vs 16 -> ~3.7x smaller
        assert!(fp16 / q > 3.5, "ratio {}", fp16 / q);
    }

    #[test]
    fn matches_quant_module_semantics() {
        // cache dequant must agree with the reference fake_quant
        let mut rng = Rng::seeded(72);
        let dim = 96;
        let cfg = NxConfig::nxfp(4);
        let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut cache = KvCache::new(dim, cfg.clone());
        cache.append(&k, &k);
        let (kd, _) = cache.dequantize(1);
        let want = crate::quant::fake_quant(&k, &cfg);
        assert_eq!(kd.row(0), &want[..]);
    }

    #[test]
    fn clear_resets() {
        let mut cache = KvCache::new(32, NxConfig::mxfp(4));
        cache.append(&vec![1.0; 32], &vec![1.0; 32]);
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.footprint_bits(), 0);
    }
}
