//! Quantized KV-cache manager (paper §7.4 "quantizing the weights and KV
//! cache", §6 deployment). Keys/values are stored in packed NxFP/MxFP/BFP
//! form — the DRAM-resident footprint — and dequantized on the fly when a
//! decode step needs the attention context.
//!
//! Storage + encode hot path: both streams live in flat [`BlockStore`]s
//! (one contiguous codes buffer each, SoA metadata), and
//! [`KvCache::append`] quantizes through the cache's resident
//! [`EncodePlan`] + [`EncodeScratch`] — zero heap allocations per appended
//! row in steady state (the stores grow amortized; use
//! [`KvCache::with_capacity`] to pre-reserve a whole context window).
//!
//! # Incremental dequantization contract
//!
//! Serving appends one row per decode step, so re-decoding the whole cache
//! every step makes per-request decode work O(S²). [`KvCache`] therefore
//! keeps a **dirty-row watermark**: [`KvCache::dequantize_into`] decodes
//! only the rows appended since the previous call into caller-owned
//! staging tensors and advances the watermark. The contract is:
//!
//! * the caller passes the *same* destination buffer (or a bit-identical
//!   copy, e.g. after a lane-to-lane slab move) across calls and does not
//!   overwrite previously decoded rows;
//! * rows `0..watermark()` in the destination are then always
//!   bit-identical to what a fresh [`KvCache::dequantize`] would produce
//!   (both paths share one decode routine), and padding rows stay zero;
//! * if the destination's contents are lost — the slot was reassigned to a
//!   lane whose previous contents are unknown — call
//!   [`KvCache::reset_watermark`] first and the next
//!   [`KvCache::dequantize_into_slab`] re-decodes every row;
//! * [`KvCache::clear`] resets both the cache and the watermark (the
//!   caller must also zero or discard its staging buffers).
//!
//! Since PR 3 the decode destination is a raw `&mut [f32]` slab — the
//! serving coordinator points it directly at the slot's lane of the batched
//! step tensors, so there is no intermediate staging mirror (see
//! `coordinator::SlotKv`).

use crate::dequant::DequantLut;
use crate::formats::{BaseFormat, BlockStore, EncodePlan, EncodeScratch, NxConfig};
use crate::tensor::Tensor2;

/// One layer's quantized K and V streams. Rows are appended per generated
/// token; each row is quantized independently in `cfg.block_size` blocks
/// along the feature dimension (matching how the paper blocks the cache).
pub struct KvCache {
    pub cfg: NxConfig,
    plan: EncodePlan,
    scratch: EncodeScratch,
    lut: DequantLut,
    pub dim: usize,
    k_store: BlockStore,
    v_store: BlockStore,
    pub len: usize,
    /// Rows already materialized by the last [`KvCache::dequantize_into`].
    clean: usize,
    blocks_per_row: usize,
}

impl KvCache {
    pub fn new(dim: usize, cfg: NxConfig) -> Self {
        Self::with_capacity(dim, cfg, 0)
    }

    /// Like [`KvCache::new`], but pre-reserves storage for `rows` appended
    /// rows so a full context window appends without reallocation.
    pub fn with_capacity(dim: usize, cfg: NxConfig, rows: usize) -> Self {
        let plan = EncodePlan::new(&cfg);
        let lut = DequantLut::from_tables(cfg.bits, &plan.tabs);
        let blocks_per_row = dim.div_ceil(cfg.block_size);
        let mut k_store = BlockStore::new(dim, cfg.block_size);
        let mut v_store = BlockStore::new(dim, cfg.block_size);
        k_store.reserve_rows(rows);
        v_store.reserve_rows(rows);
        KvCache {
            cfg,
            plan,
            scratch: EncodeScratch::new(),
            lut,
            dim,
            k_store,
            v_store,
            len: 0,
            clean: 0,
            blocks_per_row,
        }
    }

    /// Quantize and append one (k, v) row pair.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        let r = self.k_store.push_row();
        let (codes, e, nano, fmt) = self.k_store.row_slices_mut(r);
        self.plan.quantize_row_into(k, &mut self.scratch, codes, e, nano, fmt);
        let r = self.v_store.push_row();
        let (codes, e, nano, fmt) = self.v_store.row_slices_mut(r);
        self.plan.quantize_row_into(v, &mut self.scratch, codes, e, nano, fmt);
        self.len += 1;
    }

    /// Quantize and append `n` (k, v) row pairs in one bulk operation
    /// (the chunked-prefill path). Storage for the whole chunk is grown
    /// **once** per stream ([`BlockStore::push_rows`]) instead of once per
    /// token, then every row is encoded through the same
    /// `quantize_row_into` routine as [`KvCache::append`] — the packed
    /// bits are identical to `n` single-row appends by construction.
    /// `k_rows`/`v_rows` are row-major `[n, dim]`.
    pub fn append_rows(&mut self, k_rows: &[f32], v_rows: &[f32], n: usize) {
        assert_eq!(k_rows.len(), n * self.dim);
        assert_eq!(v_rows.len(), n * self.dim);
        if n == 0 {
            return;
        }
        let r0 = self.k_store.push_rows(n);
        for (i, row) in k_rows.chunks(self.dim).enumerate() {
            let (codes, e, nano, fmt) = self.k_store.row_slices_mut(r0 + i);
            self.plan.quantize_row_into(row, &mut self.scratch, codes, e, nano, fmt);
        }
        let r0 = self.v_store.push_rows(n);
        for (i, row) in v_rows.chunks(self.dim).enumerate() {
            let (codes, e, nano, fmt) = self.v_store.row_slices_mut(r0 + i);
            self.plan.quantize_row_into(row, &mut self.scratch, codes, e, nano, fmt);
        }
        self.len += n;
    }

    /// Rows already decoded into the caller's staging tensors (the
    /// dirty-row watermark). Rows `watermark()..len` are pending.
    pub fn watermark(&self) -> usize {
        self.clean
    }

    /// The packed (K, V) [`BlockStore`]s — the stored bits themselves.
    /// Exposed so the chunk-invariance tests can pin bit-identity of the
    /// packed streams across prefill budgets; hot paths never need this.
    pub fn stores(&self) -> (&BlockStore, &BlockStore) {
        (&self.k_store, &self.v_store)
    }

    /// Shared decode routine: rows `from..to` of one stream into the
    /// row-major `out` slab (`dim` floats per row). Both the full and the
    /// incremental path go through here, which is what makes them
    /// bit-identical by construction.
    fn dequant_rows(&self, store: &BlockStore, out: &mut [f32], from: usize, to: usize) {
        let base_mx = self.cfg.base == BaseFormat::Mx;
        for r in from..to {
            let row = &mut out[r * self.dim..(r + 1) * self.dim];
            for (bi, chunk) in row.chunks_mut(self.cfg.block_size).enumerate() {
                let flat = r * self.blocks_per_row + bi;
                let fmt_mx = if self.cfg.enable_am {
                    store.fmt_mx[flat] != 0
                } else {
                    base_mx
                };
                let (table, offset) = self.lut.table(fmt_mx);
                let scale = (1.0 + store.nano[flat] as f32 / 4.0)
                    * crate::util::exp2i(store.e_shared[flat] as i32 + offset);
                for (o, &c) in chunk.iter_mut().zip(store.block_codes(flat)) {
                    *o = table[c as usize] * scale;
                }
            }
        }
    }

    /// Dequantize the whole cache into `(len, dim)` tensors, padded to
    /// `pad_len` rows of zeros (decode-step artifacts take fixed shapes).
    pub fn dequantize(&self, pad_len: usize) -> (Tensor2, Tensor2) {
        assert!(pad_len >= self.len);
        let mut k = Tensor2::zeros(pad_len, self.dim);
        let mut v = Tensor2::zeros(pad_len, self.dim);
        self.dequant_rows(&self.k_store, &mut k.data, 0, self.len);
        self.dequant_rows(&self.v_store, &mut v.data, 0, self.len);
        (k, v)
    }

    /// Incrementally decode rows appended since the previous call straight
    /// into the caller's row-major `[rows >= len, dim]` slabs (a batch-lane
    /// layer region, padding pre-zeroed), advance the watermark, and return
    /// the decoded row range. See the module docs for the full contract.
    pub fn dequantize_into_slab(&mut self, k: &mut [f32], v: &mut [f32]) -> std::ops::Range<usize> {
        let need = self.len * self.dim;
        assert!(k.len() >= need && v.len() >= need, "slab too short");
        let (from, to) = (self.clean, self.len);
        self.dequant_rows(&self.k_store, k, from, to);
        self.dequant_rows(&self.v_store, v, from, to);
        self.clean = to;
        from..to
    }

    /// Tensor-shaped convenience wrapper over
    /// [`KvCache::dequantize_into_slab`] (tests and non-lane callers).
    pub fn dequantize_into(&mut self, k: &mut Tensor2, v: &mut Tensor2) -> std::ops::Range<usize> {
        assert!(k.rows >= self.len && v.rows >= self.len, "staging too short");
        assert_eq!(k.cols, self.dim);
        assert_eq!(v.cols, self.dim);
        self.dequantize_into_slab(&mut k.data, &mut v.data)
    }

    /// Forget decode progress: the next [`KvCache::dequantize_into_slab`]
    /// re-decodes every stored row. The lane-reassignment fallback — when a
    /// slot moves to a lane whose previous contents are unknown and a
    /// lane-to-lane slab copy was not possible, the packed streams are the
    /// only source of truth left.
    pub fn reset_watermark(&mut self) {
        self.clean = 0;
    }

    /// Bit-true stored footprint of the cache (both K and V).
    pub fn footprint_bits(&self) -> u64 {
        2 * self.len as u64 * self.cfg.footprint_bits(self.dim)
    }

    /// FP16 footprint of the same cache, for the savings headline.
    pub fn fp16_footprint_bits(&self) -> u64 {
        2 * (self.len * self.dim) as u64 * 16
    }

    pub fn clear(&mut self) {
        self.k_store.clear();
        self.v_store.clear();
        self.len = 0;
        self.clean = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats::mse;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_dequantize_round_trip() {
        let mut rng = Rng::seeded(71);
        let dim = 64;
        let mut cache = KvCache::new(dim, NxConfig::nxfp(5));
        let mut rows = Vec::new();
        for _ in 0..10 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(&k, &v);
            rows.push((k, v));
        }
        let (kd, vd) = cache.dequantize(16);
        for (r, (k, v)) in rows.iter().enumerate() {
            assert!(mse(kd.row(r), k) < 0.01, "row {r} K mse too big");
            assert!(mse(vd.row(r), v) < 0.01);
        }
        // padding rows are zero
        for r in 10..16 {
            assert!(kd.row(r).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn append_matches_reference_quantizer() {
        // the cache's engine path must store the exact blocks the
        // reference `formats::quantize_block` produces
        let mut rng = Rng::seeded(74);
        let dim = 45; // partial tail block
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(6), NxConfig::nxfp(5)] {
            let tabs = cfg.tables();
            let mut cache = KvCache::new(dim, cfg.clone());
            let mut appended = Vec::new();
            for _ in 0..4 {
                let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                cache.append(&k, &k);
                appended.push(k);
            }
            for (r, k) in appended.iter().enumerate() {
                for (bi, chunk) in k.chunks(cfg.block_size).enumerate() {
                    let want = crate::formats::quantize_block(chunk, &cfg, &tabs);
                    let flat = r * cache.blocks_per_row + bi;
                    assert_eq!(cache.k_store.block(flat), want, "{}", cfg.name());
                    assert_eq!(cache.v_store.block(flat), want, "{}", cfg.name());
                }
            }
        }
    }

    #[test]
    fn incremental_matches_full_dequantize() {
        let mut rng = Rng::seeded(73);
        let (dim, pad) = (48, 12);
        // odd dim -> partial tail block; cover all three format families
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(4)] {
            let mut cache = KvCache::new(dim, cfg);
            let mut k_stage = Tensor2::zeros(pad, dim);
            let mut v_stage = Tensor2::zeros(pad, dim);
            let mut decoded = 0usize;
            for chunk in [3usize, 1, 4, 2] {
                for _ in 0..chunk {
                    let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                    let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                    cache.append(&k, &v);
                }
                let range = cache.dequantize_into(&mut k_stage, &mut v_stage);
                assert_eq!(range, decoded..decoded + chunk);
                decoded += chunk;
                assert_eq!(cache.watermark(), decoded);
                // staging must be bit-identical to a fresh full decode
                let (k_full, v_full) = cache.dequantize(pad);
                assert_eq!(k_stage.data, k_full.data);
                assert_eq!(v_stage.data, v_full.data);
            }
            // no new rows -> empty range, buffers untouched
            let before = k_stage.data.clone();
            assert!(cache.dequantize_into(&mut k_stage, &mut v_stage).is_empty());
            assert_eq!(k_stage.data, before);
        }
    }

    #[test]
    fn reset_watermark_redecodes_everything() {
        // lane-reassignment fallback: after a reset, the next incremental
        // decode must rebuild the full prefix bit-identically from packed
        let mut rng = Rng::seeded(75);
        let (dim, pad) = (40, 8);
        let mut cache = KvCache::new(dim, NxConfig::nxfp(4));
        let mut k_lane = vec![0.0f32; pad * dim];
        let mut v_lane = vec![0.0f32; pad * dim];
        for _ in 0..6 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(&k, &v);
        }
        cache.dequantize_into_slab(&mut k_lane, &mut v_lane);
        assert_eq!(cache.watermark(), 6);
        // slot moved to a lane with unknown contents: reset + re-decode
        let mut new_k = vec![0.0f32; pad * dim];
        let mut new_v = vec![0.0f32; pad * dim];
        cache.reset_watermark();
        assert_eq!(cache.watermark(), 0);
        let range = cache.dequantize_into_slab(&mut new_k, &mut new_v);
        assert_eq!(range, 0..6);
        assert_eq!(new_k, k_lane);
        assert_eq!(new_v, v_lane);
    }

    #[test]
    fn with_capacity_appends_without_reallocating() {
        let dim = 64;
        let rows = 16;
        let mut cache = KvCache::with_capacity(dim, NxConfig::nxfp(4), rows);
        let cap_codes = cache.k_store.codes.capacity();
        let cap_meta = cache.k_store.e_shared.capacity();
        assert!(cap_codes >= rows * dim);
        let row: Vec<f32> = (0..dim).map(|i| i as f32 * 0.1).collect();
        for _ in 0..rows {
            cache.append(&row, &row);
        }
        // steady state: the pre-reserved buffers never grew
        assert_eq!(cache.k_store.codes.capacity(), cap_codes);
        assert_eq!(cache.k_store.e_shared.capacity(), cap_meta);
        assert_eq!(cache.len, rows);
    }

    #[test]
    fn append_rows_bit_identical_to_single_appends() {
        // bulk chunk encoding must store the exact bytes the per-token
        // path stores, incl. partial tail blocks (dim 45, block 32 ->
        // 13-element tails) and chunk splits at every offset
        let mut rng = Rng::seeded(76);
        let dim = 45;
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(4)] {
            let n = 7;
            let k_rows: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
            let v_rows: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
            let mut single = KvCache::new(dim, cfg.clone());
            for r in 0..n {
                single.append(&k_rows[r * dim..(r + 1) * dim], &v_rows[r * dim..(r + 1) * dim]);
            }
            for split in 0..=n {
                let mut bulk = KvCache::new(dim, cfg.clone());
                bulk.append_rows(&k_rows[..split * dim], &v_rows[..split * dim], split);
                bulk.append_rows(&k_rows[split * dim..], &v_rows[split * dim..], n - split);
                assert_eq!(bulk.len, n);
                assert_eq!(bulk.stores(), single.stores(), "{} split {split}", cfg.name());
            }
        }
    }

    #[test]
    fn watermark_on_empty_cache_is_a_noop() {
        let dim = 32;
        let mut cache = KvCache::new(dim, NxConfig::nxfp(4));
        let mut k = vec![7.0f32; 4 * dim];
        let mut v = vec![7.0f32; 4 * dim];
        // empty cache: decode range is empty and the slab is untouched
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..0);
        assert!(k.iter().all(|&x| x == 7.0));
        cache.reset_watermark();
        assert_eq!(cache.watermark(), 0);
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..0);
        // a zero-length slab is acceptable for a zero-length cache
        assert_eq!(cache.dequantize_into_slab(&mut [], &mut []), 0..0);
        assert_eq!(cache.footprint_bits(), 0);
    }

    #[test]
    fn watermark_at_exact_capacity_fill() {
        // fill a cache to exactly its pre-reserved context window through
        // a mix of bulk and single appends: no reallocation anywhere, and
        // the watermark decode into an exactly-sized slab stays correct
        let mut rng = Rng::seeded(77);
        let (dim, rows) = (40, 12); // partial tail block (block 32)
        let mut cache = KvCache::with_capacity(dim, NxConfig::nxfp(4), rows);
        let (cap_k_codes, cap_k_meta) = {
            let (ks, _) = cache.stores();
            (ks.codes.capacity(), ks.e_shared.capacity())
        };
        let mut k_lane = vec![0.0f32; rows * dim]; // exactly-capacity slab
        let mut v_lane = vec![0.0f32; rows * dim];
        let chunk: Vec<f32> = (0..5 * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        cache.append_rows(&chunk, &chunk, 5);
        cache.dequantize_into_slab(&mut k_lane, &mut v_lane);
        let row: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for _ in 0..3 {
            cache.append(&row, &row);
        }
        let tail: Vec<f32> = (0..4 * dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        cache.append_rows(&tail, &tail, 4);
        assert_eq!(cache.len, rows);
        assert_eq!(cache.dequantize_into_slab(&mut k_lane, &mut v_lane), 5..rows);
        assert_eq!(cache.watermark(), rows);
        // bit-identical to a from-scratch full decode
        let (k_full, v_full) = cache.dequantize(rows);
        assert_eq!(k_lane, k_full.data);
        assert_eq!(v_lane, v_full.data);
        // the context-window fill never reallocated the packed streams
        let (ks, _) = cache.stores();
        assert_eq!(ks.codes.capacity(), cap_k_codes);
        assert_eq!(ks.e_shared.capacity(), cap_k_meta);
    }

    #[test]
    fn partial_tail_blocks_after_bulk_append() {
        // dim 19 with block 16: every row ends in a 3-element tail block
        // split mid-row by the block boundary; bulk appends must decode
        // bit-identically to the reference per-row dequantize
        let mut rng = Rng::seeded(78);
        let dim = 19;
        let cfg = NxConfig::nxfp(4).with_block_size(16);
        let mut cache = KvCache::new(dim, cfg);
        let rows: Vec<f32> = (0..6 * dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        cache.append_rows(&rows, &rows, 6);
        let mut k = vec![0.0f32; 8 * dim];
        let mut v = vec![0.0f32; 8 * dim];
        assert_eq!(cache.dequantize_into_slab(&mut k, &mut v), 0..6);
        let (k_full, _) = cache.dequantize(8);
        assert_eq!(k, k_full.data);
        // tail blocks really are short
        let (ks, _) = cache.stores();
        assert_eq!(ks.blocks_per_row(), 2);
        assert_eq!(ks.block_codes(1).len(), 3);
    }

    #[test]
    fn footprint_savings_vs_fp16() {
        let mut cache = KvCache::new(128, NxConfig::nxfp(4));
        let row = vec![0.5f32; 128];
        for _ in 0..8 {
            cache.append(&row, &row);
        }
        let q = cache.footprint_bits() as f64;
        let fp16 = cache.fp16_footprint_bits() as f64;
        // 4.34 effective bits vs 16 -> ~3.7x smaller
        assert!(fp16 / q > 3.5, "ratio {}", fp16 / q);
    }

    #[test]
    fn matches_quant_module_semantics() {
        // cache dequant must agree with the reference fake_quant
        let mut rng = Rng::seeded(72);
        let dim = 96;
        let cfg = NxConfig::nxfp(4);
        let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut cache = KvCache::new(dim, cfg.clone());
        cache.append(&k, &k);
        let (kd, _) = cache.dequantize(1);
        let want = crate::quant::fake_quant(&k, &cfg);
        assert_eq!(kd.row(0), &want[..]);
    }

    #[test]
    fn clear_resets() {
        let mut cache = KvCache::new(32, NxConfig::mxfp(4));
        cache.append(&vec![1.0; 32], &vec![1.0; 32]);
        let mut k = Tensor2::zeros(4, 32);
        let mut v = Tensor2::zeros(4, 32);
        cache.dequantize_into(&mut k, &mut v);
        assert_eq!(cache.watermark(), 1);
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.watermark(), 0);
        assert_eq!(cache.footprint_bits(), 0);
    }
}
