//! Quantized KV-cache manager (paper §7.4 "quantizing the weights and KV
//! cache", §6 deployment). Keys/values are stored in packed NxFP/MxFP/BFP
//! form — the DRAM-resident footprint — and dequantized on the fly when a
//! decode step needs the attention context.
//!
//! # Incremental dequantization contract
//!
//! Serving appends one row per decode step, so re-decoding the whole cache
//! every step makes per-request decode work O(S²). [`KvCache`] therefore
//! keeps a **dirty-row watermark**: [`KvCache::dequantize_into`] decodes
//! only the rows appended since the previous call into caller-owned
//! staging tensors and advances the watermark. The contract is:
//!
//! * the caller passes the *same* staging tensors (or bit-identical
//!   copies) across calls and does not overwrite previously decoded rows;
//! * rows `0..watermark()` in the staging tensors are then always
//!   bit-identical to what a fresh [`KvCache::dequantize`] would produce
//!   (both paths share one decode routine), and padding rows stay zero;
//! * [`KvCache::clear`] resets both the cache and the watermark (the
//!   caller must also zero or discard its staging tensors).

use crate::dequant::DequantLut;
use crate::formats::{quantize_block, BaseFormat, BlockCode, FormatTables, NxConfig};
use crate::tensor::Tensor2;

/// One layer's quantized K and V streams. Rows are appended per generated
/// token; each row is quantized independently in `cfg.block_size` blocks
/// along the feature dimension (matching how the paper blocks the cache).
pub struct KvCache {
    pub cfg: NxConfig,
    tabs: FormatTables,
    lut: DequantLut,
    pub dim: usize,
    k_blocks: Vec<BlockCode>,
    v_blocks: Vec<BlockCode>,
    pub len: usize,
    /// Rows already materialized by the last [`KvCache::dequantize_into`].
    clean: usize,
    blocks_per_row: usize,
}

impl KvCache {
    pub fn new(dim: usize, cfg: NxConfig) -> Self {
        let tabs = cfg.tables();
        let lut = DequantLut::from_tables(cfg.bits, &tabs);
        let blocks_per_row = dim.div_ceil(cfg.block_size);
        KvCache {
            cfg,
            tabs,
            lut,
            dim,
            k_blocks: Vec::new(),
            v_blocks: Vec::new(),
            len: 0,
            clean: 0,
            blocks_per_row,
        }
    }

    /// Quantize and append one (k, v) row pair.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        for chunk in k.chunks(self.cfg.block_size) {
            self.k_blocks.push(quantize_block(chunk, &self.cfg, &self.tabs));
        }
        for chunk in v.chunks(self.cfg.block_size) {
            self.v_blocks.push(quantize_block(chunk, &self.cfg, &self.tabs));
        }
        self.len += 1;
    }

    /// Rows already decoded into the caller's staging tensors (the
    /// dirty-row watermark). Rows `watermark()..len` are pending.
    pub fn watermark(&self) -> usize {
        self.clean
    }

    /// Shared decode routine: rows `from..to` of one stream into `out`.
    /// Both the full and the incremental path go through here, which is
    /// what makes them bit-identical by construction.
    fn dequant_rows(&self, blocks: &[BlockCode], out: &mut Tensor2, from: usize, to: usize) {
        let base_mx = self.cfg.base == BaseFormat::Mx;
        for r in from..to {
            let row = out.row_mut(r);
            for (bi, chunk) in row.chunks_mut(self.cfg.block_size).enumerate() {
                let b = &blocks[r * self.blocks_per_row + bi];
                let fmt_mx = if self.cfg.enable_am { b.fmt_mx } else { base_mx };
                let (table, offset) = self.lut.table(fmt_mx);
                let scale = (1.0 + b.nano as f32 / 4.0)
                    * crate::util::exp2i(b.e_shared as i32 + offset);
                for (o, &c) in chunk.iter_mut().zip(&b.codes) {
                    *o = table[c as usize] * scale;
                }
            }
        }
    }

    /// Dequantize the whole cache into `(len, dim)` tensors, padded to
    /// `pad_len` rows of zeros (decode-step artifacts take fixed shapes).
    pub fn dequantize(&self, pad_len: usize) -> (Tensor2, Tensor2) {
        assert!(pad_len >= self.len);
        let mut k = Tensor2::zeros(pad_len, self.dim);
        let mut v = Tensor2::zeros(pad_len, self.dim);
        self.dequant_rows(&self.k_blocks, &mut k, 0, self.len);
        self.dequant_rows(&self.v_blocks, &mut v, 0, self.len);
        (k, v)
    }

    /// Incrementally decode rows appended since the previous call into the
    /// caller's staging tensors (`rows >= len`, `cols == dim`, padding
    /// pre-zeroed), advance the watermark, and return the decoded row
    /// range. See the module docs for the full contract.
    pub fn dequantize_into(&mut self, k: &mut Tensor2, v: &mut Tensor2) -> std::ops::Range<usize> {
        assert!(k.rows >= self.len && v.rows >= self.len, "staging too short");
        assert_eq!(k.cols, self.dim);
        assert_eq!(v.cols, self.dim);
        let (from, to) = (self.clean, self.len);
        self.dequant_rows(&self.k_blocks, k, from, to);
        self.dequant_rows(&self.v_blocks, v, from, to);
        self.clean = to;
        from..to
    }

    /// Bit-true stored footprint of the cache (both K and V).
    pub fn footprint_bits(&self) -> u64 {
        2 * self.len as u64 * self.cfg.footprint_bits(self.dim)
    }

    /// FP16 footprint of the same cache, for the savings headline.
    pub fn fp16_footprint_bits(&self) -> u64 {
        2 * (self.len * self.dim) as u64 * 16
    }

    pub fn clear(&mut self) {
        self.k_blocks.clear();
        self.v_blocks.clear();
        self.len = 0;
        self.clean = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::stats::mse;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_dequantize_round_trip() {
        let mut rng = Rng::seeded(71);
        let dim = 64;
        let mut cache = KvCache::new(dim, NxConfig::nxfp(5));
        let mut rows = Vec::new();
        for _ in 0..10 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            cache.append(&k, &v);
            rows.push((k, v));
        }
        let (kd, vd) = cache.dequantize(16);
        for (r, (k, v)) in rows.iter().enumerate() {
            assert!(mse(kd.row(r), k) < 0.01, "row {r} K mse too big");
            assert!(mse(vd.row(r), v) < 0.01);
        }
        // padding rows are zero
        for r in 10..16 {
            assert!(kd.row(r).iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn incremental_matches_full_dequantize() {
        let mut rng = Rng::seeded(73);
        let (dim, pad) = (48, 12);
        // odd dim -> partial tail block; cover all three format families
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(4)] {
            let mut cache = KvCache::new(dim, cfg);
            let mut k_stage = Tensor2::zeros(pad, dim);
            let mut v_stage = Tensor2::zeros(pad, dim);
            let mut decoded = 0usize;
            for chunk in [3usize, 1, 4, 2] {
                for _ in 0..chunk {
                    let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                    let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                    cache.append(&k, &v);
                }
                let range = cache.dequantize_into(&mut k_stage, &mut v_stage);
                assert_eq!(range, decoded..decoded + chunk);
                decoded += chunk;
                assert_eq!(cache.watermark(), decoded);
                // staging must be bit-identical to a fresh full decode
                let (k_full, v_full) = cache.dequantize(pad);
                assert_eq!(k_stage.data, k_full.data);
                assert_eq!(v_stage.data, v_full.data);
            }
            // no new rows -> empty range, buffers untouched
            let before = k_stage.data.clone();
            assert!(cache.dequantize_into(&mut k_stage, &mut v_stage).is_empty());
            assert_eq!(k_stage.data, before);
        }
    }

    #[test]
    fn footprint_savings_vs_fp16() {
        let mut cache = KvCache::new(128, NxConfig::nxfp(4));
        let row = vec![0.5f32; 128];
        for _ in 0..8 {
            cache.append(&row, &row);
        }
        let q = cache.footprint_bits() as f64;
        let fp16 = cache.fp16_footprint_bits() as f64;
        // 4.34 effective bits vs 16 -> ~3.7x smaller
        assert!(fp16 / q > 3.5, "ratio {}", fp16 / q);
    }

    #[test]
    fn matches_quant_module_semantics() {
        // cache dequant must agree with the reference fake_quant
        let mut rng = Rng::seeded(72);
        let dim = 96;
        let cfg = NxConfig::nxfp(4);
        let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut cache = KvCache::new(dim, cfg.clone());
        cache.append(&k, &k);
        let (kd, _) = cache.dequantize(1);
        let want = crate::quant::fake_quant(&k, &cfg);
        assert_eq!(kd.row(0), &want[..]);
    }

    #[test]
    fn clear_resets() {
        let mut cache = KvCache::new(32, NxConfig::mxfp(4));
        cache.append(&vec![1.0; 32], &vec![1.0; 32]);
        let mut k = Tensor2::zeros(4, 32);
        let mut v = Tensor2::zeros(4, 32);
        cache.dequantize_into(&mut k, &mut v);
        assert_eq!(cache.watermark(), 1);
        cache.clear();
        assert_eq!(cache.len, 0);
        assert_eq!(cache.watermark(), 0);
        assert_eq!(cache.footprint_bits(), 0);
    }
}
