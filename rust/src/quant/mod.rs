//! Direct-cast quantization pipeline (paper §5, Algorithm 1) over vectors
//! and matrices, with a multithreaded matrix path for checkpoint-sized
//! tensors, plus the quantized KV-cache used by the serving coordinator.
//!
//! Storage layout: all quantized codes live in a flat
//! [`BlockStore`] (one contiguous codes buffer + SoA per-block metadata —
//! see `formats/store.rs`), and encoding runs through the allocation-free
//! [`EncodePlan`] engine (`formats/encode.rs`), which is bit-identical to
//! the reference `formats::quantize_block` by contract
//! (`tests/engine_equivalence.rs`). The threaded matrix path hands each
//! thread stripe disjoint sub-slices of the store, so there is no
//! per-block allocation and no post-hoc collection.

pub mod kv_cache;
pub mod page;

use crate::formats::{BlockStore, EncodePlan, EncodeScratch, FormatTables, NxConfig};
use crate::tensor::Tensor2;

/// A quantized 1-D vector: consecutive blocks of `cfg.block_size`, stored
/// as a single-row [`BlockStore`].
#[derive(Clone, Debug)]
pub struct QuantizedVector {
    pub len: usize,
    pub block_size: usize,
    pub store: BlockStore,
}

impl QuantizedVector {
    pub fn dequantize(&self, cfg: &NxConfig) -> Vec<f32> {
        let tabs = cfg.tables();
        self.dequantize_with(&tabs)
    }

    pub fn dequantize_with(&self, tabs: &FormatTables) -> Vec<f32> {
        let mut out = vec![0.0; self.len];
        for (flat, chunk) in out.chunks_mut(self.block_size).enumerate() {
            self.store.dequantize_block_into(flat, tabs, chunk);
        }
        out
    }
}

/// A quantized 2-D tensor: `rows * ceil(cols/k)` blocks in a row-major
/// [`BlockStore`] (blocks never straddle rows).
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub block_size: usize,
    pub store: BlockStore,
}

impl QuantizedMatrix {
    pub fn blocks_per_row(&self) -> usize {
        self.cols.div_ceil(self.block_size)
    }

    pub fn dequantize(&self, cfg: &NxConfig) -> Tensor2 {
        let tabs = cfg.tables();
        let mut out = Tensor2::zeros(self.rows, self.cols);
        let bpr = self.blocks_per_row();
        for r in 0..self.rows {
            let row = out.row_mut(r);
            for (bi, chunk) in row.chunks_mut(self.block_size).enumerate() {
                self.store.dequantize_block_into(r * bpr + bi, &tabs, chunk);
            }
        }
        out
    }

    /// Pack into deployable bit-true form (straight walk of the store).
    pub fn pack(&self, cfg: &NxConfig) -> crate::formats::packed::PackedMatrix {
        crate::formats::packed::PackedMatrix::from_store(self.rows, self.cols, cfg, &self.store)
    }
}

/// Quantize a 1-D slice.
pub fn quantize_vector(v: &[f32], cfg: &NxConfig) -> QuantizedVector {
    let plan = EncodePlan::new(cfg);
    let mut scratch = EncodeScratch::new();
    let mut store = BlockStore::with_rows(1, v.len(), cfg.block_size);
    let (codes, e, nano, fmt) = store.row_slices_mut(0);
    plan.quantize_row_into(v, &mut scratch, codes, e, nano, fmt);
    QuantizedVector { len: v.len(), block_size: cfg.block_size, store }
}

/// Quantize a matrix row-wise (blocks never straddle rows, matching how the
/// paper quantizes weight matrices along the input dimension). Uses all
/// available cores for large tensors; thread stripes write disjoint ranges
/// of the pre-sized [`BlockStore`], so the parallel path allocates nothing
/// per block and collects nothing afterwards.
///
/// Builds a fresh [`EncodePlan`] — checkpoint-scale callers quantizing many
/// tensors under one config should build the plan once and use
/// [`quantize_matrix_with`] instead.
pub fn quantize_matrix(t: &Tensor2, cfg: &NxConfig) -> QuantizedMatrix {
    quantize_matrix_with(t, cfg, &EncodePlan::new(cfg))
}

/// [`quantize_matrix`] with a caller-owned [`EncodePlan`] (one plan per
/// config instead of one per tensor; the plan is read-only and shared by
/// every thread stripe). `plan` must have been built for `cfg`.
pub fn quantize_matrix_with(t: &Tensor2, cfg: &NxConfig, plan: &EncodePlan) -> QuantizedMatrix {
    debug_assert_eq!(plan.cfg.name(), cfg.name(), "plan built for a different config");
    let mut store = BlockStore::with_rows(t.rows, t.cols, cfg.block_size);
    let bpr = store.blocks_per_row();
    let n_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(t.rows.max(1));
    // Small tensors: stay single-threaded to avoid spawn overhead.
    if t.rows * t.cols < 1 << 16 || n_threads == 1 {
        let mut scratch = EncodeScratch::new();
        for r in 0..t.rows {
            let (codes, e, nano, fmt) = store.row_slices_mut(r);
            plan.quantize_row_into(t.row(r), &mut scratch, codes, e, nano, fmt);
        }
        return QuantizedMatrix {
            rows: t.rows,
            cols: t.cols,
            block_size: cfg.block_size,
            store,
        };
    }
    let chunk_rows = t.rows.div_ceil(n_threads);
    std::thread::scope(|s| {
        let code_chunks = store.codes.chunks_mut(chunk_rows * t.cols);
        let e_chunks = store.e_shared.chunks_mut(chunk_rows * bpr);
        let nano_chunks = store.nano.chunks_mut(chunk_rows * bpr);
        let fmt_chunks = store.fmt_mx.chunks_mut(chunk_rows * bpr);
        for (ti, (((codes, e), nano), fmt)) in
            code_chunks.zip(e_chunks).zip(nano_chunks).zip(fmt_chunks).enumerate()
        {
            let t = &t;
            s.spawn(move || {
                let mut scratch = EncodeScratch::new();
                let lo = ti * chunk_rows;
                let hi = ((ti + 1) * chunk_rows).min(t.rows);
                for r in lo..hi {
                    let i = r - lo;
                    plan.quantize_row_into(
                        t.row(r),
                        &mut scratch,
                        &mut codes[i * t.cols..(i + 1) * t.cols],
                        &mut e[i * bpr..(i + 1) * bpr],
                        &mut nano[i * bpr..(i + 1) * bpr],
                        &mut fmt[i * bpr..(i + 1) * bpr],
                    );
                }
            });
        }
    });
    QuantizedMatrix { rows: t.rows, cols: t.cols, block_size: cfg.block_size, store }
}

/// Quantize-then-dequantize (direct-cast "fake quantization"): what the
/// model sees after a weight tensor round-trips through the format.
pub fn fake_quant(v: &[f32], cfg: &NxConfig) -> Vec<f32> {
    quantize_vector(v, cfg).dequantize(cfg)
}

/// Fake-quantize a matrix in place (row-blocked).
pub fn fake_quant_matrix(t: &Tensor2, cfg: &NxConfig) -> Tensor2 {
    quantize_matrix(t, cfg).dequantize(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;
    use crate::tensor::stats::mse;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn vector_round_trip_len_preserved() {
        let mut rng = Rng::seeded(31);
        for len in [1usize, 31, 32, 33, 64, 100] {
            let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let q = quantize_vector(&v, &NxConfig::nxfp(4));
            assert_eq!(q.dequantize(&NxConfig::nxfp(4)).len(), len);
        }
    }

    #[test]
    fn matrix_multithreaded_matches_single_threaded() {
        let mut rng = Rng::seeded(32);
        // big enough to trigger the threaded path
        let t = Tensor2::random_normal(512, 512, 1.0, &mut rng);
        let cfg = NxConfig::nxfp(4);
        let q = quantize_matrix(&t, &cfg);
        // reference-path check on a few sampled rows
        let tabs = cfg.tables();
        let bpr = q.blocks_per_row();
        for &r in &[0usize, 100, 511] {
            for (bi, chunk) in t.row_blocks(r, cfg.block_size).enumerate() {
                let b = crate::formats::quantize_block(chunk, &cfg, &tabs);
                assert_eq!(q.store.block(r * bpr + bi), b);
            }
        }
    }

    #[test]
    fn shared_plan_matches_per_tensor_plan() {
        // one EncodePlan threaded across many tensors must produce the
        // exact stores a fresh per-tensor plan would
        let mut rng = Rng::seeded(37);
        let cfg = NxConfig::nxfp(5);
        let plan = crate::formats::EncodePlan::new(&cfg);
        for rows in [1usize, 7, 33] {
            let t = Tensor2::random_normal(rows, 50, 0.8, &mut rng);
            let a = quantize_matrix(&t, &cfg);
            let b = quantize_matrix_with(&t, &cfg, &plan);
            assert_eq!(a.store, b.store, "rows={rows}");
        }
    }

    #[test]
    fn store_matches_reference_blocks_exactly() {
        // the engine-backed store must hold the exact blocks the reference
        // path produces, per flat index, including partial tails
        let mut rng = Rng::seeded(36);
        let t = Tensor2::random_normal(5, 45, 1.5, &mut rng);
        for cfg in [NxConfig::bfp(5), NxConfig::mxfp(6), NxConfig::nxfp(4)] {
            let q = quantize_matrix(&t, &cfg);
            let tabs = cfg.tables();
            let bpr = q.blocks_per_row();
            for r in 0..t.rows {
                for (bi, chunk) in t.row_blocks(r, cfg.block_size).enumerate() {
                    let want = crate::formats::quantize_block(chunk, &cfg, &tabs);
                    assert_eq!(q.store.block(r * bpr + bi), want, "{}", cfg.name());
                }
            }
        }
    }

    #[test]
    fn mse_ordering_nxfp_beats_mxfp_beats_random() {
        // the paper's core claim at 4 bits, on Gaussian weights
        let mut rng = Rng::seeded(33);
        let v: Vec<f32> = (0..32 * 256).map(|_| rng.normal_f32(0.0, 0.02)).collect();
        let e_bfp = mse(&v, &fake_quant(&v, &NxConfig::bfp(4)));
        let e_mx = mse(&v, &fake_quant(&v, &NxConfig::mxfp(4)));
        let e_nx = mse(&v, &fake_quant(&v, &NxConfig::nxfp(4)));
        assert!(e_nx < e_mx, "NxFP4 {e_nx} !< MxFP4 {e_mx}");
        // Fig. 8: ~10-45% reduction
        assert!(e_nx < 0.95 * e_mx, "expected >5% MSE gain, got {e_nx}/{e_mx}");
        assert!(e_bfp > 0.0);
    }

    #[test]
    fn higher_bits_monotonically_reduce_error() {
        let mut rng = Rng::seeded(34);
        let v: Vec<f32> = (0..32 * 64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut last = f64::INFINITY;
        for bits in [4u8, 5, 6] {
            let e = mse(&v, &fake_quant(&v, &NxConfig::nxfp(bits)));
            assert!(e < last, "bits={bits}: {e} !< {last}");
            last = e;
        }
    }

    #[test]
    fn prop_fakequant_bounded_relative_error() {
        // every dequantized element stays within the block's worst-case step
        proptest::check_default("fakequant-bounded", |rng| {
            let len = 1 + rng.below(64);
            let scale = crate::util::exp2i(rng.range(-20, 20) as i32);
            let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0) * scale).collect();
            let cfg = NxConfig::nxfp(4);
            let out = fake_quant(&v, &cfg);
            let maxabs = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (i, (&x, &y)) in v.iter().zip(&out).enumerate() {
                // FP4 worst-case quantization step is 2 in the scaled domain
                // (gap 4->6), i.e. half-gap 1; scale ~ maxabs/6 with NM up to
                // 1.75x; allow generous bound maxabs/2.
                if (x - y).abs() > maxabs / 2.0 + 1e-30 {
                    return Err(format!("elem {i}: {x} -> {y} (maxabs {maxabs})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dequant_values_on_grid() {
        // Re-quantizing a dequantized vector is exact (grid fixed point) for
        // formats without NanoMantissa. With NM the two-candidate rule of
        // Algorithm 1 recomputes the nano candidate from the (already
        // shrunken) quantized max, so NM fake-quant is deliberately NOT
        // idempotent — AM+CR alone is.
        proptest::check_default("fakequant-idempotent", |rng| {
            let len = 1 + rng.below(64);
            let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let am_cr = NxConfig { enable_nm: false, ..NxConfig::nxfp(5) };
            for cfg in [NxConfig::bfp(5), NxConfig::mxfp(5), am_cr] {
                let q1 = fake_quant(&v, &cfg);
                let q2 = fake_quant(&q1, &cfg);
                if q1 != q2 {
                    return Err(format!("{} not idempotent", cfg.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sign_symmetry_without_cr() {
        // without CR the grid is symmetric: q(-v) == -q(v)
        proptest::check_default("sign-symmetry", |rng| {
            let v: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let neg: Vec<f32> = v.iter().map(|x| -x).collect();
            for cfg in [NxConfig::bfp(4), NxConfig::mxfp(4), NxConfig::nxfp_nm_am(4)] {
                let a = fake_quant(&v, &cfg);
                let b = fake_quant(&neg, &cfg);
                for (x, y) in a.iter().zip(&b) {
                    if *x != -*y {
                        return Err(format!("{}: {x} vs {y}", cfg.name()));
                    }
                }
            }
            Ok(())
        });
    }
}
