//! Refcounted page pool for the paged quantized KV cache.
//!
//! A **page** is a fixed-row-count [`BlockStore`] fragment of one KV
//! stream (one layer, K or V side): `page_rows` quantized rows, laid out
//! exactly like the flat stream so pages concatenate bit-identically via
//! [`BlockStore::append_rows_from`]. Slots no longer own their rows —
//! they hold page tables of [`PageId`]s into a shared [`PagePool`], which
//! is what makes prefix sharing possible: two slots whose prompts share a
//! token prefix share the packed pages covering it (refcount bump, zero
//! copies) and copy-on-write only the partially-covered tail page at the
//! first divergent append.
//!
//! Ownership rules:
//!
//! * `alloc` returns a page with `refs == 1`; `retain`/`release` adjust
//!   the count; a page hitting zero refs is cleared and recycled through
//!   the free list (ids are reused, never invalidated while referenced).
//! * A holder may mutate a page **only while `refs == 1`**. To append
//!   into a shared tail, call [`PagePool::cow`] first: it clones the
//!   adopted prefix into a fresh page and drops the caller's ref on the
//!   shared one.
//! * Footprint dedup: each page carries an `accounted` flag so completed
//!   requests can charge shared pages to the metrics exactly once
//!   ([`PagePool::mark_accounted`]).
//!
//! The pool is deliberately single-threaded (`Rc<RefCell<PagePool>>` at
//! the engine layer) — the decode engine itself is `!Send`.

use crate::formats::BlockStore;

/// Index into the pool's entry arena. Stable while any ref is held.
pub type PageId = usize;

/// Default rows per KV page (`--kv-page-rows`). Small enough that short
/// shared prefixes still dedup whole pages, large enough that page-table
/// overhead stays negligible next to the packed rows.
pub const DEFAULT_KV_PAGE_ROWS: usize = 16;

struct Entry {
    store: BlockStore,
    refs: u32,
    /// Set once a completed request has charged this page to the
    /// dedup-aware footprint; cleared on recycle.
    accounted: bool,
}

/// Shared arena of refcounted KV pages. See the module docs for the
/// ownership contract.
pub struct PagePool {
    page_rows: usize,
    entries: Vec<Entry>,
    free: Vec<PageId>,
    /// Pages with `refs >= 2` right now (O(1) shared-page gauge).
    shared: usize,
    /// Lifetime counters for metrics/tests.
    cow_copies: u64,
    pages_allocated: u64,
}

impl PagePool {
    pub fn new(page_rows: usize) -> Self {
        assert!(page_rows > 0, "page_rows must be positive");
        PagePool {
            page_rows,
            entries: Vec::new(),
            free: Vec::new(),
            shared: 0,
            cow_copies: 0,
            pages_allocated: 0,
        }
    }

    /// Rows a full (non-tail) page holds.
    #[inline]
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Allocate an empty page for a stream of the given geometry
    /// (`refs == 1`). Recycles a free slot when one exists.
    pub fn alloc(&mut self, row_len: usize, block_size: usize) -> PageId {
        self.pages_allocated += 1;
        match self.free.pop() {
            Some(id) => {
                let e = &mut self.entries[id];
                debug_assert_eq!(e.refs, 0);
                e.store = BlockStore::new(row_len, block_size);
                e.refs = 1;
                e.accounted = false;
                id
            }
            None => {
                self.entries.push(Entry {
                    store: BlockStore::new(row_len, block_size),
                    refs: 1,
                    accounted: false,
                });
                self.entries.len() - 1
            }
        }
    }

    /// Add a reference (prefix adoption shares the page).
    pub fn retain(&mut self, id: PageId) {
        let e = &mut self.entries[id];
        assert!(e.refs > 0, "retain on dead page {id}");
        e.refs += 1;
        if e.refs == 2 {
            self.shared += 1;
        }
    }

    /// Drop a reference; a page hitting zero is cleared and recycled.
    pub fn release(&mut self, id: PageId) {
        let e = &mut self.entries[id];
        assert!(e.refs > 0, "release on dead page {id}");
        e.refs -= 1;
        if e.refs == 1 {
            self.shared -= 1;
        } else if e.refs == 0 {
            e.store.clear();
            e.accounted = false;
            self.free.push(id);
        }
    }

    #[inline]
    pub fn refs(&self, id: PageId) -> u32 {
        self.entries[id].refs
    }

    /// Rows currently stored in page `id`.
    #[inline]
    pub fn rows(&self, id: PageId) -> usize {
        self.entries[id].store.rows
    }

    #[inline]
    pub fn store(&self, id: PageId) -> &BlockStore {
        &self.entries[id].store
    }

    /// Mutable store access — callers must hold the page exclusively
    /// (`refs == 1`); shared tails go through [`PagePool::cow`] first.
    #[inline]
    pub fn store_mut(&mut self, id: PageId) -> &mut BlockStore {
        debug_assert_eq!(self.entries[id].refs, 1, "mutating shared page {id}");
        &mut self.entries[id].store
    }

    /// Copy-on-write split: clone the first `keep_rows` rows of `id` into
    /// a fresh exclusively-owned page, then drop the caller's ref on `id`.
    /// Returns the new page. The donor (and any other sharers) are
    /// untouched beyond the refcount drop.
    pub fn cow(&mut self, id: PageId, keep_rows: usize) -> PageId {
        let copy = self.entries[id].store.clone_prefix(keep_rows);
        let new_id = self.alloc(copy.row_len, copy.block_size);
        self.entries[new_id].store = copy;
        self.release(id);
        self.cow_copies += 1;
        new_id
    }

    /// First-charge gate for the dedup-aware footprint: returns `true`
    /// exactly once per page lifetime (until the page is recycled).
    pub fn mark_accounted(&mut self, id: PageId) -> bool {
        let e = &mut self.entries[id];
        assert!(e.refs > 0, "accounting dead page {id}");
        !std::mem::replace(&mut e.accounted, true)
    }

    /// Pages currently holding at least one reference.
    pub fn live_pages(&self) -> usize {
        self.entries.len() - self.free.len()
    }

    /// Pages currently referenced by two or more holders.
    #[inline]
    pub fn shared_pages(&self) -> usize {
        self.shared
    }

    /// Lifetime count of COW splits performed.
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Lifetime count of page allocations (including COW clones).
    pub fn pages_allocated(&self) -> u64 {
        self.pages_allocated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled_page(pool: &mut PagePool, rows: usize, seed: u8) -> PageId {
        let id = pool.alloc(5, 2);
        let st = pool.store_mut(id);
        st.push_rows(rows);
        for (i, c) in st.codes.iter_mut().enumerate() {
            *c = seed.wrapping_add(i as u8);
        }
        for flat in 0..st.n_blocks() {
            st.e_shared[flat] = seed as i16 + flat as i16;
        }
        id
    }

    #[test]
    fn alloc_retain_release_lifecycle() {
        let mut pool = PagePool::new(4);
        let a = pool.alloc(8, 4);
        assert_eq!((pool.refs(a), pool.live_pages(), pool.shared_pages()), (1, 1, 0));
        pool.retain(a);
        assert_eq!((pool.refs(a), pool.shared_pages()), (2, 1));
        pool.release(a);
        assert_eq!((pool.refs(a), pool.shared_pages()), (1, 0));
        pool.release(a);
        assert_eq!(pool.live_pages(), 0);
        // freed id is recycled, fresh and empty
        let b = pool.alloc(8, 4);
        assert_eq!(b, a);
        assert_eq!(pool.rows(b), 0);
        assert_eq!(pool.refs(b), 1);
        assert_eq!(pool.pages_allocated(), 2);
    }

    #[test]
    fn cow_clones_prefix_and_leaves_donor_intact() {
        let mut pool = PagePool::new(4);
        let donor = filled_page(&mut pool, 4, 10);
        let donor_snapshot = pool.store(donor).clone();
        pool.retain(donor); // second holder adopts, then diverges at row 2
        let fresh = pool.cow(donor, 2);
        assert_ne!(fresh, donor);
        assert_eq!(pool.store(fresh), &donor_snapshot.clone_prefix(2));
        assert_eq!(pool.store(donor), &donor_snapshot);
        assert_eq!((pool.refs(donor), pool.refs(fresh)), (1, 1));
        assert_eq!(pool.shared_pages(), 0);
        assert_eq!(pool.cow_copies(), 1);
    }

    #[test]
    fn cow_on_sole_ref_releases_original() {
        let mut pool = PagePool::new(4);
        let a = filled_page(&mut pool, 3, 1);
        let b = pool.cow(a, 3);
        // sole holder: original is recycled, clone carries the rows
        assert_eq!(pool.live_pages(), 1);
        assert_eq!(pool.rows(b), 3);
    }

    #[test]
    fn mark_accounted_fires_once_per_lifetime() {
        let mut pool = PagePool::new(4);
        let a = pool.alloc(8, 4);
        assert!(pool.mark_accounted(a));
        assert!(!pool.mark_accounted(a));
        pool.retain(a);
        assert!(!pool.mark_accounted(a)); // sharers still see it charged
        pool.release(a);
        pool.release(a);
        let b = pool.alloc(8, 4);
        assert_eq!(b, a);
        assert!(pool.mark_accounted(b)); // recycle resets the flag
    }

    #[test]
    #[should_panic(expected = "release on dead page")]
    fn release_underflow_panics() {
        let mut pool = PagePool::new(4);
        let a = pool.alloc(8, 4);
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn shared_gauge_tracks_multiple_pages() {
        let mut pool = PagePool::new(4);
        let a = pool.alloc(8, 4);
        let b = pool.alloc(8, 4);
        pool.retain(a);
        pool.retain(b);
        pool.retain(b);
        assert_eq!(pool.shared_pages(), 2);
        pool.release(b);
        assert_eq!(pool.shared_pages(), 2); // b still at 2 refs
        pool.release(b);
        assert_eq!(pool.shared_pages(), 1);
        pool.release(a);
        assert_eq!(pool.shared_pages(), 0);
        assert_eq!(pool.live_pages(), 2);
    }
}
