//! L3 training driver: runs the AOT-compiled `train_step` artifact in a
//! loop, holding parameters and Adam state as XLA literals between steps.
//!
//! Artifact contract (pinned against `python/compile/aot.py`):
//!
//! * `train_step` inputs: `P` param tensors, `P` Adam-m tensors, `P` Adam-v
//!   tensors, `step` (f32 scalar, 1-based), `tokens` (i32 `[B, S+1]`);
//!   outputs: `P` params, `P` m, `P` v, `loss` (f32 scalar).
//! * `eval_step` inputs: `P` params + `tokens`; outputs: `sum_nll`, `count`.
//! * `score_step` inputs: `P` params + `tokens`; outputs `nll [B, S]`.

use anyhow::Result;
use std::rc::Rc;

use crate::models::{Checkpoint, Corpus, LmSpec};
use crate::runtime::{lit, Runtime, Step};
use crate::tensor::Tensor2;
use crate::util::rng::Rng;

/// Training hyperparameters (must match the values baked into the artifact
/// only where they change shapes; lr/β are traced into the artifact).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub batch: usize,
    pub steps: u32,
    pub log_every: u32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { batch: 16, steps: 300, log_every: 10, seed: 42 }
    }
}

/// Convert a checkpoint into parameter literals (flattening contract order).
pub fn params_to_literals(ck: &Checkpoint) -> Result<Vec<xla::Literal>> {
    ck.params.iter().map(|(_, t)| lit::from_tensor(t)).collect()
}

/// Convert parameter literals back into a checkpoint for a spec.
pub fn literals_to_checkpoint(spec: &LmSpec, lits: &[xla::Literal]) -> Result<Checkpoint> {
    let specs = spec.param_specs();
    anyhow::ensure!(lits.len() == specs.len(), "literal count mismatch");
    let params = specs
        .into_iter()
        .zip(lits)
        .map(|((name, r, c), l)| Ok((name, lit::to_tensor(l, r, c)?)))
        .collect::<Result<Vec<_>>>()?;
    Ok(Checkpoint { params, steps: 0, final_loss: f32::NAN })
}

/// Stateful trainer holding params + Adam moments as literals.
pub struct Trainer {
    pub spec: LmSpec,
    step_fn: Rc<Step>,
    pub params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    pub t: u32,
    pub losses: Vec<(u32, f32)>,
    rng: Rng,
}

impl Trainer {
    /// Initialize from a fresh (or resumed) checkpoint.
    pub fn new(
        rt: &mut Runtime,
        spec: LmSpec,
        init: &Checkpoint,
        cfg: &TrainConfig,
    ) -> Result<Self> {
        init.check_spec(&spec)?;
        let step_fn = rt.load("train_step")?;
        let params = params_to_literals(init)?;
        let zeros = |spec: &LmSpec| -> Result<Vec<xla::Literal>> {
            spec.param_specs()
                .iter()
                .map(|(_, r, c)| lit::from_tensor(&Tensor2::zeros(*r, *c)))
                .collect()
        };
        let m = zeros(&spec)?;
        let v = zeros(&spec)?;
        Ok(Trainer {
            spec,
            step_fn,
            params,
            m,
            v,
            t: init.steps,
            losses: Vec::new(),
            rng: Rng::seeded(cfg.seed),
        })
    }

    /// One optimizer step on a sampled batch; returns the loss.
    pub fn step(&mut self, corpus: &Corpus, batch: usize) -> Result<f32> {
        self.t += 1;
        let tokens = corpus.batch(&corpus.train, batch, self.spec.seq_len, &mut self.rng);
        let tok_lit =
            lit::from_i32(&tokens, &[batch as i64, self.spec.seq_len as i64 + 1])?;
        let p = self.params.len();
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * p + 2);
        args.extend(self.params.iter());
        args.extend(self.m.iter());
        args.extend(self.v.iter());
        let t_lit = lit::scalar_f32(self.t as f32);
        args.push(&t_lit);
        args.push(&tok_lit);
        let mut out = self.step_fn.run(&args)?;
        anyhow::ensure!(out.len() == 3 * p + 1, "train_step returned {} outputs", out.len());
        let loss = lit::first_f32(&out[3 * p])?;
        // replace state (drain from the back to avoid reallocating)
        out.truncate(3 * p);
        let v_new = out.split_off(2 * p);
        let m_new = out.split_off(p);
        self.params = out;
        self.m = m_new;
        self.v = v_new;
        Ok(loss)
    }

    /// Run the full loop, recording the loss curve.
    pub fn train(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainConfig,
        mut on_log: impl FnMut(u32, f32),
    ) -> Result<()> {
        for i in 0..cfg.steps {
            let loss = self.step(corpus, cfg.batch)?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {} ({loss})", self.t);
            if i % cfg.log_every == 0 || i + 1 == cfg.steps {
                self.losses.push((self.t, loss));
                on_log(self.t, loss);
            }
        }
        Ok(())
    }

    /// Export current parameters as a checkpoint.
    pub fn checkpoint(&self) -> Result<Checkpoint> {
        let mut ck = literals_to_checkpoint(&self.spec, &self.params)?;
        ck.steps = self.t;
        ck.final_loss = self.losses.last().map(|&(_, l)| l).unwrap_or(f32::NAN);
        Ok(ck)
    }
}

