//! PJRT runtime: load AOT-lowered HLO text artifacts (produced by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::tensor::Tensor2;

/// Owns the PJRT client and a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<Step>>,
    artifacts_dir: PathBuf,
}

/// One compiled step function (e.g. `train_step`, `eval_step`).
pub struct Step {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Runtime {
    /// CPU PJRT client rooted at an artifacts directory.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, cache: HashMap::new(), artifacts_dir: artifacts_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<artifacts_dir>/<name>.hlo.txt`, compile, and cache.
    pub fn load(&mut self, name: &str) -> Result<std::rc::Rc<Step>> {
        if let Some(s) = self.cache.get(name) {
            return Ok(s.clone());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let step = std::rc::Rc::new(self.compile_file(name, &path)?);
        self.cache.insert(name.to_string(), step.clone());
        Ok(step)
    }

    /// Compile an HLO text file without caching (tests, one-offs).
    pub fn compile_file(&self, name: &str, path: &Path) -> Result<Step> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        Ok(Step { name: name.to_string(), exe })
    }
}

impl Step {
    /// Execute with literal inputs; returns the flattened tuple outputs.
    /// (All artifacts are lowered with `return_tuple=True`.)
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<L>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))
    }
}

/// Literal conversion helpers shared by the training/eval/serving drivers.
pub mod lit {
    use super::*;

    /// f32 tensor -> 2-D literal.
    pub fn from_tensor(t: &Tensor2) -> Result<xla::Literal> {
        xla::Literal::vec1(&t.data)
            .reshape(&[t.rows as i64, t.cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    /// f32 slice -> literal with explicit dims.
    pub fn from_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "dims {dims:?} != len {}", data.len());
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    /// i32 token slab -> literal with explicit dims.
    pub fn from_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "dims {dims:?} != len {}", data.len());
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    pub fn scalar_f32(x: f32) -> xla::Literal {
        xla::Literal::scalar(x)
    }

    pub fn scalar_i32(x: i32) -> xla::Literal {
        xla::Literal::scalar(x)
    }

    /// literal -> f32 vec (any shape, row-major).
    pub fn to_f32(l: &xla::Literal) -> Result<Vec<f32>> {
        l.to_vec::<f32>().map_err(|e| anyhow!("literal to f32: {e:?}"))
    }

    /// literal -> Tensor2 given expected dims.
    pub fn to_tensor(l: &xla::Literal, rows: usize, cols: usize) -> Result<Tensor2> {
        let v = to_f32(l)?;
        anyhow::ensure!(v.len() == rows * cols, "len {} != {rows}x{cols}", v.len());
        Ok(Tensor2::from_vec(rows, cols, v))
    }

    /// first element of a literal as f32 (loss scalars etc.)
    pub fn first_f32(l: &xla::Literal) -> Result<f32> {
        let v = to_f32(l)?;
        v.first().copied().context("empty literal")
    }
}
